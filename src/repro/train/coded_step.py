"""The coded train step: the paper's gradient coding wired into a generic
shard_map train step usable by every zoo architecture.

Layout: batch arrives in the redundant coded layout (n, d, b, ...) — dim 0
sharded over the data axes (n workers), dim 1 the worker's d assigned
subsets.  The step (manual over data axes, GSPMD-auto over 'model'):

  1. scans the d subsets, computing each subset's gradient with
     ``jax.value_and_grad`` (activation memory = 1 subset; compute
     redundancy d is the paper's intended cost),
  2. folds each subset gradient into the l/m encoding on the fly with the
     worker's coefficient rows C[i, j, :] (paper eq. 17/18 — never
     materializes the (d, l) partial-gradient matrix),
  3. multiplies by the responder mask (stragglers transmit nothing; proves
     the decode is independent of straggler payloads),
  4. packs the coded encodings into the static ``PackPlan``'s bucketed flat
     wire buffers (default; ``packed=False`` keeps the per-leaf escape
     hatch) and decodes the summed gradient with the host-computed float64
     weights W (zero rows at stragglers) via the gather or a2a schedule —
     one collective choreography + one fused contraction per bucket,
  5. runs the optimizer update (replicated over data axes, model-sharded).

All coding phases are delegated to a ``repro.coding.Codec``: ``schedule``
picks the collective choreography (gather / a2a / psum — see
``repro.coding.schedules``), ``backend`` the encode/decode implementation
("auto" -> Pallas kernels on TPU, einsum reference elsewhere; "pallas" forces
the kernels, in interpret mode off-TPU).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import coding
from repro.compat import collectives_ok, shard_map
from repro.core import GradCode
from repro.models import api as model_api
from repro.optim import Optimizer

from . import sharding
from .pipeline import CompiledPipeline, PipelineFns

PyTree = Any

# §Perf lever: pin the coded encodings to their model sharding before the
# manual collective (see _enc_spec below).  Default False = recorded baseline;
# flipped by the dry-run's --opt enc_constraint.
ENC_CONSTRAINT = False


@dataclasses.dataclass(frozen=True)
class StepArtifacts:
    """Everything the launcher needs to run one coded train step.

    Carries the jit-able step builder (``step(batch_shapes) -> (fn, in_specs,
    out_specs)``), the per-leaf coding plans, the bound ``Codec``, the static
    ``PackPlan`` of the packed wire (None on the per-leaf path), the
    per-worker subset-load vector (uniform codes: ``(d,) * n``; hetero codes:
    the plan's ragged loads), and whether the step was built in
    partial-recovery mode (the executable then takes a 7th ``err_factor``
    input and emits a ``decode_err_bound`` metric).
    """
    step: Callable
    in_specs: tuple
    out_specs: tuple
    plans: PyTree
    coded_fraction: float
    codec: coding.Codec | None = None
    pack_plan: coding.PackPlan | None = None
    loads: tuple[int, ...] = ()
    partial: bool = False
    pipelined: bool = False
    fuse_apply: bool = False
    spec: "coding.SchemeSpec | None" = None  # the resolved scheme levers
    pipeline: Callable | None = None   # (batch_shapes) -> PipelineFns
    # memoized jitted executables, keyed by (batch signature, donate): the
    # bench's donated steady-state step and the autotuner's telemetry step
    # share ONE executable instead of tracing twice (and `instrumented`
    # wraps exactly the `compiled` object, never a private re-jit)
    _exe_cache: dict = dataclasses.field(default_factory=dict, init=False,
                                         repr=False, compare=False)

    # ---- benchmark / driver hooks --------------------------------------
    @staticmethod
    def _batch_sig(batch) -> tuple:
        flat, treedef = jax.tree.flatten(batch)
        return (tuple((tuple(x.shape), str(x.dtype)) for x in flat),
                str(treedef))

    def compiled(self, batch, donate: bool = False):
        """Jit the step for a batch (arrays or ShapeDtypeStructs).

        Collapses the `arts.step(shapes) -> jax.jit(fn)` dance every driver
        repeats; straggler patterns stay *inputs* to the returned callable
        (`fn(params, opt_state, batch, W, mask, rho)`), so one executable
        serves every drop pattern.

        donate=True donates params/opt_state (`donate_argnums=(0, 1)`,
        matching the Trainer's jit) so steady-state timing loops reuse the
        update buffers — callers must then thread the returned params/state
        into the next call instead of replaying the originals.

        Memoized per (batch shapes, donate): repeat callers — the bench's
        timing loop, `instrumented` telemetry wrappers, HLO dumps — all
        receive the same jitted callable, so the step is traced and
        compiled at most once per signature.
        """
        key = self._batch_sig(batch) + (bool(donate),)
        if key not in self._exe_cache:
            shapes = jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), batch)
            fn, _, _ = self.step(shapes)
            self._exe_cache[key] = (jax.jit(fn, donate_argnums=(0, 1))
                                    if donate else jax.jit(fn))
        return self._exe_cache[key]

    def compiled_pipeline(self, batch, donate: bool = True) -> CompiledPipeline:
        """Jit the pipelined fill/steady/drain triple for a batch.

        donate=True donates params/opt-state AND the wire-state buffers of
        ``steady``/``drain`` (the double-buffer swap reuses the retired
        buffer's memory); ``fill`` never donates — its params are reused by
        the first steady call.  Memoized like :meth:`compiled`.
        """
        if self.pipeline is None:
            raise ValueError("step was not built with pipelined=True")
        key = ("pipeline",) + self._batch_sig(batch) + (bool(donate),)
        if key not in self._exe_cache:
            shapes = jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), batch)
            fns: PipelineFns = self.pipeline(shapes)
            B = fns.num_buffers
            if donate:
                steady = jax.jit(fns.steady,
                                 donate_argnums=(0, 1) + tuple(range(6, 6 + B)))
                drain = jax.jit(fns.drain,
                                donate_argnums=(0, 1) + tuple(range(3, 3 + B)))
            else:
                steady, drain = jax.jit(fns.steady), jax.jit(fns.drain)
            self._exe_cache[key] = CompiledPipeline(
                fill=jax.jit(fns.fill), steady=steady, drain=drain,
                num_buffers=B)
        return self._exe_cache[key]

    def lowered(self, batch, cfg, optimizer):
        """Lower (don't execute) the step for abstract inputs: returns the
        jax ``Lowered`` — ``.compile().as_text()`` feeds HLO analysis such
        as the collective-count guards (`repro.launch.hlo_cost.analyze`).
        Collapses the pshapes/oshapes/W/mask/rho ShapeDtypeStruct dance the
        HLO test and the coding_packed bench would otherwise both hand-roll.
        """
        shapes = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), batch)
        fn, _, _ = self.step(shapes)
        pshapes = jax.eval_shape(
            lambda: model_api.init(jax.random.PRNGKey(0), cfg))
        oshapes = jax.eval_shape(optimizer.init, pshapes)
        code = self.codec.code
        args = [pshapes, oshapes, shapes,
                jax.ShapeDtypeStruct((code.n, code.m), jnp.float32),
                jax.ShapeDtypeStruct((code.n,), jnp.float32),
                jax.ShapeDtypeStruct((code.n, code.d), jnp.float32)]
        if self.partial:
            args.append(jax.ShapeDtypeStruct((), jnp.float32))
        return jax.jit(fn).lower(*args)

    def instrumented(self, batch, on_time: Callable[[float], None],
                     donate: bool = False):
        """Telemetry hook: a ``compiled(...)`` executable that reports its
        blocked wall-clock.

        Returns a callable with the step signature that runs the jitted
        step, blocks until every output is ready, and passes the elapsed
        seconds to ``on_time`` before returning the outputs.  This is the
        convenience wrapper for drivers that build their own loop; the
        ``Trainer`` performs the equivalent inline timing itself (its jit
        cache is keyed per scheme) and feeds the same blocked wall-clock
        into the `repro.tune` step-cost calibration.
        """
        fn = self.compiled(batch, donate=donate)

        def timed(*args):
            t0 = time.perf_counter()
            out = fn(*args)
            jax.block_until_ready(out)
            on_time(time.perf_counter() - t0)
            return out

        # the executable actually timed — tests assert it IS the memoized
        # `compiled(...)` object (identical HLO by identity, not by diff)
        timed.inner = fn
        return timed

    def step_inputs(self, stragglers=()) -> dict[str, jax.Array]:
        """Drop-pattern hook: device-ready `W`/`mask`/`rho` for a straggler
        set (the host-side float64 solve for this responder pattern).  On a
        partial-recovery step the dict also carries the pattern's
        ``err_factor`` certificate scalar (the executable's 7th input)."""
        assert self.codec is not None
        inp = coding.make_step_inputs(self.codec.code, stragglers,
                                      partial=self.partial)
        return {k: jnp.asarray(v) for k, v in inp.items()}


def _data_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a != "model")


def _axis_prod(mesh, axes) -> int:
    return int(np.prod([mesh.shape[a] for a in axes]))


def pipelining_supported(mesh, schedule: str = "gather") -> bool:
    """Whether the async pipelined step is available on this runtime/scheme:
    the schedule must carry an encoding (psum has no wire to double-buffer)
    and the runtime must lower native collectives inside shard_map — the
    degraded old-jax psum-emulated path still *builds* a correct pipeline
    (tests exercise its parity) but gains nothing from overlap, so drivers
    use this predicate to skip it gracefully."""
    from repro.coding import get_schedule
    return (get_schedule(schedule).uses_encoding
            and collectives_ok(mesh, _data_axes(mesh)))


def make_coded_train_step(cfg, code: GradCode, mesh, optimizer: Optimizer,
                          *, spec: coding.SchemeSpec | None = None,
                          grad_scale: float | None = None,
                          schedule: str | None = None,
                          encode_dtype: str | None = None,
                          backend: str | coding.CodecBackend | None = None,
                          packed: bool | None = None,
                          partial: bool | None = None,
                          pipelined: bool | None = None,
                          fuse_apply: bool | None = None) -> StepArtifacts:
    """Build the shard_map'd coded train step for one architecture.

    code: a uniform :class:`~repro.core.schemes.GradCode` or a heterogeneous
    :class:`~repro.core.hetero.HeteroCode` — the batch layout's subset-slot
    count is ``code.d`` (the max per-worker load for hetero plans, whose
    padded slots carry zero encode/rho weight).

    spec: a :class:`repro.coding.SchemeSpec` bundling every scheme lever —
    the same instance a ``CodedServer`` accepts, so train and serve run one
    scheme from one value.  The per-lever kwargs below are the deprecated
    spelling (``DeprecationWarning``; cannot be combined with ``spec=``)
    and produce bitwise-identical artifacts to the equivalent spec.

    grad_scale: decoded gradients are multiplied by this (default 1/k with
    k = ``code.num_subsets`` so the update equals uncoded *mean*-gradient
    descent when per-subset losses are means; the paper's linear workload
    uses sum losses and scale 1).  Workload-specific, hence not a spec
    lever.

    encode_dtype: wire dtype of the transmitted encodings (the paper uses
    f32; "bfloat16" halves the collective bytes at ~3 decimal digits of
    gradient precision — a beyond-paper lever recorded in §Perf).

    backend: codec compute backend — "auto" | "ref" | "pallas" | "interpret"
    or a ``coding.CodecBackend`` instance.  (The pre-PR-1 ``use_kernels``
    boolean is gone; ``SchemeSpec.backend`` is the one spelling.)

    packed (default True): aggregate coded leaves through the bucketed flat
    wire buffers of ``repro.coding.packing`` — O(1) collectives and one
    fused decode contraction per bucket per step, and the psum-fallback
    leaves ride a single flat all-reduce.  ``packed=False`` is the per-leaf
    escape hatch (one collective + one skinny contraction per coded leaf),
    bit-identical by construction.

    partial (default False): build the step in partial-recovery mode — the
    executable takes a 7th scalar input ``err_factor`` (from
    ``make_step_inputs(..., partial=True)``, which then accepts straggler
    sets *larger* than the design ``s`` instead of raising) and emits a
    ``decode_err_bound`` metric: ``err_factor * sqrt(sum_j ||g_j||^2)``,
    an upper bound on the L2 error of the least-squares decoded gradient
    over the subsets that kept at least one live holder.

    pipelined (default False): additionally build the async three-phase
    step (``StepArtifacts.pipeline`` / ``compiled_pipeline``): fill
    encodes one batch into double-buffered wire-bucket state, steady
    decodes the in-flight buffers (stale-by-one) while encoding the
    current batch at pre-update params — the decode collective and the
    encode compute are dataflow-independent, so XLA overlaps them — and
    drain retires the last buffers.  The encode folds each subset gradient
    straight into the 128-aligned wire layout (``Codec.encode_into``, the
    accumulating encode kernel) instead of materialise-then-pack.
    Requires ``packed=True``, an encoding schedule (not psum) and
    ``partial=False``; the synchronous executable is still built and is
    byte-identical to the non-pipelined build.  Parity contract: fill
    immediately followed by drain == the synchronous step, bit for bit.

    fuse_apply: fuse the per-bucket decode contraction with the optimizer
    update (``Codec.decode_apply_packed``: decode + SGD-momentum + param
    write in one kernel on the gather schedule).  Only valid for
    ``optimizer.kind == "sgd"``.  Params and momentum stay bit-identical
    to the synchronous step (the kernel replicates its op sequence), but
    the ``grad_norm`` metric sums squares in bucket order instead of leaf
    order (~1e-6 relative drift), so the default (None) resolves to False
    and the fully bit-exact path stays the default.  Pipelined-only.
    """
    spec = coding.resolve_scheme_spec(
        spec, dict(schedule=schedule, backend=backend, packed=packed,
                   partial=partial, pipelined=pipelined,
                   fuse_apply=fuse_apply, encode_dtype=encode_dtype),
        caller="make_coded_train_step")
    schedule, backend = spec.schedule, spec.backend
    packed, partial, pipelined = spec.packed, spec.partial, spec.pipelined
    encode_dtype, fuse_apply = spec.encode_dtype, spec.fuse_apply
    data_axes = _data_axes(mesh)
    n = _axis_prod(mesh, data_axes)
    if code.n != n:
        raise ValueError(f"code.n={code.n} != data-parallel degree {n}")
    ms = mesh.shape["model"]
    loss_fn = model_api.make_loss(cfg)
    k_subsets = getattr(code, "num_subsets", n)
    if grad_scale is None:
        grad_scale = 1.0 if cfg.family == "linear" else 1.0 / k_subsets

    codec = coding.make_codec(code, schedule=schedule, backend=backend,
                              wire_dtype=encode_dtype)
    # Old-jax shard_map partial-auto cannot lower scan/all_gather/all_to_all
    # inside the manual region when a >1 auto (model) axis remains: unroll the
    # subset loop and decode via the schedules' psum emulation there.
    degraded = not collectives_ok(mesh, data_axes)

    if pipelined:
        if not codec.schedule.uses_encoding:
            raise ValueError(
                "pipelined=True needs an encoding schedule (gather/a2a); "
                "the psum baseline has no wire to double-buffer")
        if not packed:
            raise ValueError(
                "pipelined=True requires packed=True: the wire state IS the "
                "PackPlan's bucketed flat buffers")
        if partial:
            raise ValueError(
                "pipelined partial-recovery is unsupported: the err_factor "
                "certificate is computed from the same step's subset "
                "gradients and cannot ride the stale-by-one wire")
    fuse = False if fuse_apply is None else bool(fuse_apply)
    if fuse and not pipelined:
        raise ValueError("fuse_apply is a pipelined-step lever; "
                         "pass pipelined=True")
    if fuse and optimizer.kind != "sgd":
        raise ValueError(
            f"fuse_apply supports optimizer.kind='sgd' only (the fused "
            f"kernel replicates the SGD-momentum rule); got "
            f"{optimizer.kind or 'opaque'!r}")

    def scan_subsets(f, init, xs):
        if not degraded:
            return jax.lax.scan(f, init, xs)
        carry = init
        for i in range(code.d):
            carry, _ = f(carry, jax.tree.map(lambda x: x[i], xs))
        return carry, None

    # --- shapes / specs ------------------------------------------------
    pshapes = jax.eval_shape(lambda: model_api.init(jax.random.PRNGKey(0), cfg))
    pspecs = sharding.param_specs(pshapes, ms)
    oshapes = jax.eval_shape(optimizer.init, pshapes)
    ospecs = sharding.opt_state_specs(oshapes, pspecs)
    plans = codec.plan(pshapes, pspecs)
    coded_frac = codec.coded_fraction(pshapes, plans)
    # §Tentpole (packed wire): static layout of every coded leaf's encoding
    # into bucketed 128-aligned flat buffers (bucket key: wire dtype x
    # effective model sharding).  Computed once here; the step then issues
    # one collective choreography + one fused contraction per bucket.
    pplan = (codec.pack_plan(pshapes, plans, specs=pspecs, model_size=ms)
             if packed and codec.schedule.uses_encoding else None)
    flat_plans = jax.tree.leaves(
        plans, is_leaf=lambda x: isinstance(x, coding.LeafPlan))

    # §Perf lever (enc_constraint): the encoding of a model-sharded leaf can
    # silently lose its 'model' sharding at the manual-collective boundary
    # (GSPMD resharding — grok's 10 TB all-gather).  This computes the spec
    # each encoding *should* keep: dims = [group_dim] + rest, model entries
    # preserved.
    def _enc_spec(pl, spec):
        if not pl.coded:
            return None
        entries = [e if e == "model" else None for e in tuple(spec)]
        del entries[pl.group_dim]
        return P(*([None] + entries))

    enc_specs = jax.tree.map(
        _enc_spec, plans, pspecs,
        is_leaf=lambda x: isinstance(x, coding.LeafPlan))

    C = jnp.asarray(code.C, jnp.float32)           # (n, d, m) host constant

    # The per-worker rows of C/mask/rho enter the shard_map body sharded over
    # the data axes (dim 0), so each worker reads its own row locally — no
    # axis_index/dynamic gather in the step (axis_index lowers to PartitionId,
    # which SPMD partitioning rejects when GSPMD-auto axes remain).
    def body(params, opt_state, batch, W, mask, rho, Csh, Wsh, ef=None):
        # local batch leaves: (1, d, b, ...) -> (d, b, ...)
        lb = jax.tree.map(lambda x: x[0], batch)
        Ci = Csh[0]       # (d, m)   this worker's coefficient rows
        W_row = Wsh[0]    # (m,)     this worker's decode-weight row
        rho_i = rho[0]    # (d,)
        mask_i = mask[0]  # ()

        def per_subset(carry, xs):
            if partial:
                enc, small, loss_acc, gss_acc = carry
            else:
                enc, small, loss_acc = carry
            sub, cj, rj = xs
            lval, g = jax.value_and_grad(loss_fn)(params, sub)

            def fold(e, gleaf, pl):
                if not pl.coded:
                    return e + rj * gleaf.astype(jnp.float32)
                contrib = codec.encode_leaf(gleaf.astype(jnp.float32), cj, pl)
                # contribution arrives as (Dg/m, *rest-moved); match e's layout
                return e + contrib

            enc = jax.tree.map(fold, enc, g, plans)
            if partial:
                # rho-weighted subset gradient sumsq: psummed it becomes
                # sum_j ||g_j||^2 over covered subsets — the certificate's
                # gradient-norm term
                gss = sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                          for l in jax.tree.leaves(g))
                return (enc, small, loss_acc + rj * lval,
                        gss_acc + rj * gss), None
            return (enc, small, loss_acc + rj * lval), None

        init = (jax.tree.map(codec.encoding_zero, params, plans),
                None, jnp.zeros((), jnp.float32))
        if partial:
            init = init + (jnp.zeros((), jnp.float32),)
            (enc, _, loss_sum, gss_sum), _ = scan_subsets(
                per_subset, init, (lb, Ci, rho_i))
        else:
            (enc, _, loss_sum), _ = scan_subsets(per_subset, init,
                                                 (lb, Ci, rho_i))

        # stragglers transmit nothing — zero the payload to prove independence
        enc = jax.tree.map(
            lambda e, pl: codec.to_wire(e, mask_i) if pl.coded else e,
            enc, plans)
        if ENC_CONSTRAINT:
            flat_e, td = jax.tree.flatten(enc)
            flat_s = td.flatten_up_to(enc_specs)
            flat_p = [p for p in jax.tree.leaves(
                plans, is_leaf=lambda x: isinstance(x, coding.LeafPlan))]
            flat_e = [jax.lax.with_sharding_constraint(e, s)
                      if (pl.coded and s is not None and "model" in tuple(s))
                      else e
                      for e, s, pl in zip(flat_e, flat_s, flat_p)]
            enc = td.unflatten(flat_e)

        if pplan is not None:
            # packed path: coded leaves ride the plan's flat buckets (one
            # collective + one fused (n, L) contraction each); the psum
            # fallback leaves are summed through a single concatenated
            # all-reduce instead of one per leaf.
            flat_enc, td = jax.tree.flatten(enc)
            flat_grads = list(flat_enc)
            bufs = codec.pack(flat_enc, pplan)
            decs = [codec.decode_packed(b, W, data_axes, W_row=W_row,
                                        emulate=degraded) for b in bufs]
            for i, g_ in codec.unpack(decs, pplan).items():
                flat_grads[i] = g_
            for i, g_ in coding.psum_fallback(flat_enc, flat_plans,
                                              data_axes).items():
                flat_grads[i] = g_
            grads = td.unflatten(flat_grads)
        else:
            def dec_one(e, pl):
                if not pl.coded:
                    return jax.lax.psum(e, data_axes)
                return codec.decode_leaf(e, W, pl, data_axes,
                                         W_row=W_row, emulate=degraded)

            grads = jax.tree.map(dec_one, enc, plans)
        grads = jax.tree.map(lambda g_: g_ * grad_scale, grads)
        gnorm = jnp.sqrt(sum(jnp.sum(g_ * g_) for g_ in jax.tree.leaves(grads)))
        # responders' view, normalised by the subset count (= n uniformly)
        loss_global = jax.lax.psum(loss_sum * mask_i, data_axes) / k_subsets

        new_params, new_opt = optimizer.update(grads, opt_state, params)
        metrics = {"loss": loss_global[None], "grad_norm": gnorm[None]}
        if partial:
            bound = ef * jnp.sqrt(jax.lax.psum(gss_sum, data_axes))
            metrics["decode_err_bound"] = bound[None]
        return new_params, new_opt, metrics

    # psum baseline: plain rho-weighted all-reduce (uncoded / straggler-aware)
    def body_psum(params, opt_state, batch, W, mask, rho, Csh, Wsh, ef=None):
        lb = jax.tree.map(lambda x: x[0], batch)
        rho_i = rho[0]
        mask_i = mask[0]

        def per_subset(carry, xs):
            acc, loss_acc = carry
            sub, rj = xs
            lval, g = jax.value_and_grad(loss_fn)(params, sub)
            acc = jax.tree.map(lambda a, g_: a + rj * g_.astype(jnp.float32), acc, g)
            return (acc, loss_acc + rj * lval), None

        init = (jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
                jnp.zeros((), jnp.float32))
        (acc, loss_sum), _ = scan_subsets(per_subset, init, (lb, rho_i))
        grads = jax.tree.map(lambda a: jax.lax.psum(a, data_axes) * grad_scale, acc)
        gnorm = jnp.sqrt(sum(jnp.sum(g_ * g_) for g_ in jax.tree.leaves(grads)))
        loss_global = jax.lax.psum(loss_sum * mask_i, data_axes) / k_subsets
        new_params, new_opt = optimizer.update(grads, opt_state, params)
        metrics = {"loss": loss_global[None], "grad_norm": gnorm[None]}
        if partial:
            # the psum baseline carries no code: rho already drops uncovered
            # subsets exactly, so the certificate term is identically zero
            metrics["decode_err_bound"] = jnp.zeros((1,), jnp.float32)
        return new_params, new_opt, metrics

    fn = body_psum if not codec.schedule.uses_encoding else body

    # --- pipelined three-phase bodies -----------------------------------
    # Shared static tables: where every coded leaf lands in the wire
    # buckets (fused-encode fold targets) and how the psum-fallback leaves
    # + the masked loss scalar lay out in the flat (S,) side buffer.
    if pipelined:
        flat_pshapes = jax.tree.leaves(pshapes)
        slot_items = [(bi, s) for bi, b in enumerate(pplan.buckets)
                      for s in b.slots]
        small_ix = [i for i, pl_ in enumerate(flat_plans) if not pl_.coded]
        small_shapes = [tuple(flat_pshapes[i].shape) for i in small_ix]
        small_sizes = [int(np.prod(sh)) for sh in small_shapes]

    def _encode_wire(params, lb, Ci, rho_i, mask_i):
        """One batch's backward + fused encode: scan the d subsets, folding
        each subset gradient straight into the per-bucket f32 wire
        accumulators (``Codec.encode_into`` — no materialise-then-pack
        copy) and the rho-weighted psum-fallback accumulators.  Returns
        (per-bucket wire buffers in the wire dtype, (S,) f32 side buffer =
        concat(small-leaf flats) + [masked loss]).  Bit-identical to the
        synchronous body's fold -> to_wire -> pack_bucket: the add order
        per element is the same and the padding gaps stay exactly zero."""
        def per_subset(carry, xs):
            accs, smalls, loss_acc = carry
            sub, cj, rj = xs
            lval, g = jax.value_and_grad(loss_fn)(params, sub)
            flat_g = jax.tree.leaves(g)
            accs = list(accs)
            for bi, slot in slot_items:
                accs[bi] = codec.encode_into(
                    accs[bi], flat_g[slot.leaf_index].astype(jnp.float32),
                    cj, slot)
            smalls = tuple(sm + rj * flat_g[i].astype(jnp.float32)
                           for sm, i in zip(smalls, small_ix))
            return (tuple(accs), smalls, loss_acc + rj * lval), None

        init = (tuple(jnp.zeros((b.size,), jnp.float32)
                      for b in pplan.buckets),
                tuple(jnp.zeros(sh, jnp.float32) for sh in small_shapes),
                jnp.zeros((), jnp.float32))
        (accs, smalls, loss_sum), _ = scan_subsets(per_subset, init,
                                                   (lb, Ci, rho_i))
        wires = tuple(codec.to_wire(a, mask_i) for a in accs)
        side = jnp.concatenate([s_.reshape(-1) for s_ in smalls]
                               + [(loss_sum * mask_i)[None]])
        return wires, side

    def _decode_update(params, opt_state, W, W_row, wires, side):
        """Decode the in-flight wire + side buffers and apply the update:
        the synchronous step's phases 4-5 operating on state instead of
        locally produced encodings.  Op-for-op identical to the sync body
        (bitwise parity) on the default path; with ``fuse_apply`` the coded
        leaves ride the fused decode-plus-apply kernel instead."""
        side_sum = jax.lax.psum(side, data_axes)
        loss_global = side_sum[-1] / k_subsets
        flat_params, ptd = jax.tree.flatten(params)
        small_grads: dict[int, jax.Array] = {}
        off = 0
        for i, sz, sh in zip(small_ix, small_sizes, small_shapes):
            small_grads[i] = (jax.lax.slice_in_dim(side_sum, off, off + sz)
                              .reshape(sh) * grad_scale)
            off += sz

        if not fuse:
            decs = [codec.decode_packed(w, W, data_axes, W_row=W_row,
                                        emulate=degraded) for w in wires]
            flat_grads: list = [None] * len(flat_params)
            for i, g_ in codec.unpack(decs, pplan).items():
                flat_grads[i] = g_ * grad_scale
            for i, g_ in small_grads.items():
                flat_grads[i] = g_
            grads = ptd.unflatten(flat_grads)
            gnorm = jnp.sqrt(sum(jnp.sum(g_ * g_)
                                 for g_ in jax.tree.leaves(grads)))
            new_params, new_opt = optimizer.update(grads, opt_state, params)
        else:
            hy = optimizer.hyper
            flat_mu = ptd.flatten_up_to(opt_state["mu"])
            p_bufs = codec.pack_params(flat_params, pplan)
            mu_bufs = codec.pack_params(flat_mu, pplan)
            new_p_bufs, new_mu_bufs, ss_parts = [], [], []
            for w, pb, mb in zip(wires, p_bufs, mu_bufs):
                pn, mn, ss = codec.decode_apply_packed(
                    w, W, pb, mb, data_axes, lr=hy["lr"],
                    momentum=hy["momentum"], scale=grad_scale,
                    W_row=W_row, emulate=degraded)
                new_p_bufs.append(pn)
                new_mu_bufs.append(mn)
                ss_parts.append(ss)
            # small leaves ride the plain optimizer update (zero grads at
            # coded positions — their state is overwritten from the fused
            # buffers right below)
            flat_gz = [small_grads.get(i,
                                       jnp.zeros(flat_params[i].shape,
                                                 jnp.float32))
                       for i in range(len(flat_params))]
            new_params, new_opt = optimizer.update(
                ptd.unflatten(flat_gz), opt_state, params)
            flat_np = ptd.flatten_up_to(new_params)
            flat_nmu = ptd.flatten_up_to(new_opt["mu"])
            for i, v in codec.unpack_params(new_p_bufs, pplan,
                                            flat_params).items():
                flat_np[i] = v
            for i, v in codec.unpack_params(new_mu_bufs, pplan,
                                            flat_mu).items():
                flat_nmu[i] = v
            new_params = ptd.unflatten(flat_np)
            new_opt = {"mu": ptd.unflatten(flat_nmu)}
            gnorm = jnp.sqrt(sum(ss_parts)
                             + sum(jnp.sum(g_ * g_)
                                   for g_ in small_grads.values()))

        metrics = {"loss": loss_global[None], "grad_norm": gnorm[None]}
        return new_params, new_opt, metrics

    def body_fill(params, batch, mask, rho, Csh):
        """Pipeline fill: encode one batch, emit wire state, no update."""
        lb = jax.tree.map(lambda x: x[0], batch)
        wires, side = _encode_wire(params, lb, Csh[0], rho[0], mask[0])
        return tuple(w[None] for w in wires) + (side[None],)

    def body_steady(params, opt_state, batch, W, mask, rho, Csh, Wsh,
                    *wire_state):
        """Steady state: decode the in-flight wire (pattern of the PREVIOUS
        call — its W arrives now) and apply the stale-by-one update, while
        encoding the current batch at the pre-update params; the collective
        and the backward pass share no data dependency, so XLA overlaps
        them."""
        lb = jax.tree.map(lambda x: x[0], batch)
        prev_wires = tuple(w[0] for w in wire_state[:-1])
        prev_side = wire_state[-1][0]
        new_params, new_opt, metrics = _decode_update(
            params, opt_state, W, Wsh[0], prev_wires, prev_side)
        wires, side = _encode_wire(params, lb, Csh[0], rho[0], mask[0])
        return ((new_params, new_opt, metrics)
                + tuple(w[None] for w in wires) + (side[None],))

    def body_drain(params, opt_state, W, Wsh, *wire_state):
        """Drain: retire the last in-flight buffers — decode + update only."""
        prev_wires = tuple(w[0] for w in wire_state[:-1])
        prev_side = wire_state[-1][0]
        return _decode_update(params, opt_state, W, Wsh[0],
                              prev_wires, prev_side)

    # --- wrap in shard_map over the data axes (model stays auto/GSPMD) --
    # shard_map's in/out_specs may only mention the manual (data) axes; the
    # 'model' placement is carried by the jit in_shardings (GSPMD auto).
    def _strip(tree):
        keep = set(data_axes)

        def f(s):
            def ok(e):
                if e is None:
                    return None
                if isinstance(e, tuple):
                    return e if all(x in keep for x in e) else None
                return e if e in keep else None
            return P(*[ok(e) for e in s])

        return jax.tree.map(f, tree, is_leaf=lambda x: isinstance(x, P))

    def make(batch_shapes):
        bspecs = sharding.batch_specs(batch_shapes, data_axes)
        # worker-row operands: dim 0 split over the (flattened) data axes
        dspec = P(data_axes if len(data_axes) > 1 else data_axes[0])
        in_specs = (pspecs, ospecs, bspecs, P(), P(), P())
        mspecs = {"loss": P(), "grad_norm": P()}
        if partial:
            in_specs = in_specs + (P(),)          # the err_factor scalar
            mspecs["decode_err_bound"] = P()
        out_specs = (pspecs, ospecs, mspecs)
        smapped = shard_map(fn, mesh=mesh,
                            in_specs=(_strip((pspecs, ospecs, bspecs, P()))
                                      + (dspec, dspec, dspec, dspec)
                                      + ((P(),) if partial else ())),
                            out_specs=_strip(out_specs),
                            axis_names=set(data_axes), check_vma=False)

        # W enters twice: replicated (decode needs all n rows) and split
        # over workers (each worker's own row, for the emulated decode);
        # mask/rho/C are split so each worker sees only its own row
        if partial:
            def stepfn(params, opt_state, batch, W, mask, rho, err_factor):
                return smapped(params, opt_state, batch, W, mask, rho, C, W,
                               err_factor)
        else:
            def stepfn(params, opt_state, batch, W, mask, rho):
                return smapped(params, opt_state, batch, W, mask, rho, C, W)

        return stepfn, in_specs, out_specs

    def make_pipeline(batch_shapes) -> PipelineFns:
        """Build the un-jitted fill/steady/drain triple for one batch shape.

        Wire-state arrays are (n, L_b) / (n, S) with dim 0 split over the
        data axes — each worker's shard is its own wire buffer, so the
        state round-trips through jit without resharding.
        """
        bspecs = sharding.batch_specs(batch_shapes, data_axes)
        dspec = P(data_axes if len(data_axes) > 1 else data_axes[0])
        mspecs = {"loss": P(), "grad_norm": P()}
        nbuf = len(pplan.buckets) + 1          # bucket buffers + side buffer
        wire_specs = (dspec,) * nbuf

        fill_sm = shard_map(
            body_fill, mesh=mesh,
            in_specs=_strip((pspecs, bspecs)) + (dspec, dspec, dspec),
            out_specs=wire_specs,
            axis_names=set(data_axes), check_vma=False)
        steady_sm = shard_map(
            body_steady, mesh=mesh,
            in_specs=(_strip((pspecs, ospecs, bspecs, P()))
                      + (dspec, dspec, dspec, dspec) + wire_specs),
            out_specs=_strip((pspecs, ospecs, mspecs)) + wire_specs,
            axis_names=set(data_axes), check_vma=False)
        drain_sm = shard_map(
            body_drain, mesh=mesh,
            in_specs=(_strip((pspecs, ospecs, P())) + (dspec,) + wire_specs),
            out_specs=_strip((pspecs, ospecs, mspecs)),
            axis_names=set(data_axes), check_vma=False)

        def fillfn(params, batch, mask, rho):
            return fill_sm(params, batch, mask, rho, C)

        def steadyfn(params, opt_state, batch, W, mask, rho, *wire):
            return steady_sm(params, opt_state, batch, W, mask, rho, C, W,
                             *wire)

        def drainfn(params, opt_state, W, *wire):
            return drain_sm(params, opt_state, W, W, *wire)

        return PipelineFns(fill=fillfn, steady=steadyfn, drain=drainfn,
                           num_buffers=nbuf)

    return StepArtifacts(step=make, in_specs=(pspecs, ospecs), out_specs=None,
                         plans=plans, coded_fraction=coded_frac, codec=codec,
                         pack_plan=pplan,
                         loads=tuple(getattr(code, "loads", (code.d,) * n)),
                         partial=partial, pipelined=pipelined,
                         fuse_apply=fuse, spec=spec,
                         pipeline=make_pipeline if pipelined else None)
