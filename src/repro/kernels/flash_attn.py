"""Pallas TPU kernel: flash attention forward (beyond-paper extension).

Motivated by §Perf: the dominant memory-roofline term of every dense
train_4k lowering was attention softmax traffic.  The JAX-level fix
(`attn_remat`, EXPERIMENTS §Perf pair C) removes the stored residuals; this
kernel is the TPU-native endpoint of the same idea — the (Sq x Sk) matrix
never leaves VMEM at all.

Design (MXU/VMEM-shaped):
- grid (BH, nq, nk); the trailing kv axis is iterated sequentially on TPU,
  so the running (m, l, acc) online-softmax state lives in VMEM scratch and
  carries across kv blocks; outputs are written on the last kv step.
- block shapes: q (1, bq, hd), k/v (1, bk, hd), out (1, bq, hd) with
  bq, bk multiples of 128 for MXU alignment (hd = 64..256 in the zoo).
- mask kinds: causal / full / sliding-window, computed from absolute block
  offsets — no mask tensor is materialized anywhere.

Validated in interpret mode against the pure-jnp online-softmax oracle
(models.common.online_attention) over shape/dtype/mask sweeps
(tests/test_kernels.py).  GQA is handled by the ops-level wrapper
(kv heads broadcast per query group).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  bq: int, bk: int, nk: int, scale: float, mask_kind: str,
                  window: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)                 # (bq, hd)
    k = k_ref[0].astype(jnp.float32)                 # (bk, hd)
    v = v_ref[0].astype(jnp.float32)
    s = jnp.dot(q, k.T) * scale                      # (bq, bk)

    qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    if mask_kind == "causal":
        valid = kpos <= qpos
    elif mask_kind == "window":
        valid = (kpos <= qpos) & (kpos > qpos - window)
    else:
        valid = jnp.ones((bq, bk), bool)
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_scr[...]                              # (bq,)
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + p.sum(axis=1)
    acc_scr[...] = acc_scr[...] * corr[:, None] + jnp.dot(p, v)
    m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("mask_kind", "window",
                                             "block_q", "block_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    mask_kind: str = "causal", window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False) -> jax.Array:
    """q: (BH, Sq, hd); k/v: (BH, Sk, hd) -> (BH, Sq, hd).

    The (Sq x Sk) score matrix exists only blockwise in VMEM.
    """
    BH, Sq, hd = q.shape
    Sk = k.shape[1]
    bq = min(block_q, Sq)
    while Sq % bq:
        bq -= 1
    bk = min(block_k, Sk)
    while Sk % bk:
        bk -= 1
    nq, nk = Sq // bq, Sk // bk
    kern = functools.partial(
        _flash_kernel, bq=bq, bk=bk, nk=nk, scale=1.0 / np.sqrt(hd),
        mask_kind=mask_kind, window=window)
    return pl.pallas_call(
        kern,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)


def flash_attention_gqa(q: jax.Array, k: jax.Array, v: jax.Array,
                        q_per_kv: int, **kw) -> jax.Array:
    """Model-layout wrapper: q (B,S,H,hd), k/v (B,S,Hkv,hd) -> (B,S,H,hd)."""
    B, Sq, H, hd = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, hd)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3), q_per_kv, axis=1) \
        .reshape(B * H, Sk, hd)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3), q_per_kv, axis=1) \
        .reshape(B * H, Sk, hd)
    out = flash_attention(qf, kf, vf, **kw)
    return out.reshape(B, H, Sq, hd).transpose(0, 2, 1, 3)
