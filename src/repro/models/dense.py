"""Dense decoder-only transformer LM (llama/qwen family): GQA + SwiGLU,
scan-over-layers with remat, KV-cache serving (dense or sliding-window)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import common as cm


# ------------------------------------------------------------------- init
def init(key, cfg):
    kl, ke, ko = jax.random.split(key, 3)
    dt = cm.pdtype(cfg)

    def layer_init(k):
        ka, km = jax.random.split(k)
        return {
            "ln1": jnp.ones((cfg.d_model,), dt),
            "attn": cm.attn_params(ka, cfg, dt),
            "ln2": jnp.ones((cfg.d_model,), dt),
            "mlp": cm.mlp_params(km, cfg, dt),
        }

    return {
        "embed": cm.dense_init(ke, (cfg.vocab, cfg.d_model), cfg.d_model, dt),
        "layers": cm.stacked_init(layer_init, kl, cfg.n_layers),
        "ln_f": jnp.ones((cfg.d_model,), dt),
        "unembed": cm.dense_init(ko, (cfg.d_model, cfg.vocab), cfg.d_model, dt),
    }


# ---------------------------------------------------------------- forward
def _block(x, lp, cfg, pos, mask_kind, window):
    x = x + cm.self_attention(lp["attn"], cfg, cm.rms_norm(x, lp["ln1"]), pos,
                              mask_kind=mask_kind, window=window)
    x = x + cm.swiglu(lp["mlp"], cm.rms_norm(x, lp["ln2"]))
    return x


def forward(params, cfg, tokens, *, window: int = 0):
    """tokens: (B, S) -> logits (B, S, V)."""
    B, S = tokens.shape
    x = cm.embed_tokens(params["embed"], tokens, cm.cdtype(cfg))
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    mk = "window" if window else "causal"
    x = cm.scan_layers(lambda h, lp: _block(h, lp, cfg, pos, mk, window),
                       x, params["layers"])
    x = cm.rms_norm(x, params["ln_f"])
    return cm.unembed(x, params["unembed"])


def loss(params, cfg, batch):
    """batch: {"tokens": (B, S), "labels": (B, S)} -> mean xent."""
    logits = forward(params, cfg, batch["tokens"])
    return cm.softmax_xent(logits, batch["labels"])


# ---------------------------------------------------------------- serving
def cache_spec(cfg, B: int, S: int, *, window: int = 0):
    """ShapeDtypeStructs for the KV cache (``S`` = max context; a sliding
    window stores min(S, window) slots)."""
    slots = min(S, window) if window else S
    dt = cm.cdtype(cfg)
    kv = jax.ShapeDtypeStruct((cfg.n_layers, B, slots, cfg.n_kv_heads, cfg.head_dim_), dt)
    return {"k": kv, "v": kv, "pos": jax.ShapeDtypeStruct((), jnp.int32)}


def init_cache(cfg, B: int, S: int, *, window: int = 0):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), cache_spec(cfg, B, S, window=window))


def prefill(params, cfg, tokens, cache_len: int, *, window: int = 0):
    """Run the prompt, return (last-token logits, filled cache).

    For a sliding-window cache only the last ``window`` positions are kept.
    """
    B, S = tokens.shape
    x = cm.embed_tokens(params["embed"], tokens, cm.cdtype(cfg))
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    mk = "window" if window else "causal"
    slots = min(cache_len, window) if window else cache_len

    def block_with_cache(x, lp):
        h = cm.rms_norm(x, lp["ln1"])
        y, k, v = cm.self_attention_with_kv(lp["attn"], cfg, h, pos,
                                            mask_kind=mk, window=window)
        x = x + y
        x = x + cm.swiglu(lp["mlp"], cm.rms_norm(x, lp["ln2"]))
        # keep the last `slots` positions, padded at the front if S < slots
        kk = cm.pack_cache(k, slots, window)
        vv = cm.pack_cache(v, slots, window)
        return x, (kk, vv)

    def step(carry, lp):
        x2, kv = jax.remat(block_with_cache)(carry, lp)
        return x2, kv

    x, (ks, vs) = jax.lax.scan(step, x, params["layers"])
    x = cm.rms_norm(x[:, -1:], params["ln_f"])
    logits = cm.unembed(x, params["unembed"])[:, 0]
    cache = {"k": ks, "v": vs, "pos": jnp.asarray(S, jnp.int32)}
    return logits, cache


def decode_step(params, cfg, cache, token, *, window: int = 0):
    """One decode step.  token: (B,) int32; cache from cache_spec/prefill.
    Returns (logits (B, V), new cache).  ``cache["pos"]`` is the absolute
    position of the token being written."""
    pos = cache["pos"]
    x = cm.embed_tokens(params["embed"], token[:, None], cm.cdtype(cfg))

    def block(x, lp_kv):
        lp, (kc, vc) = lp_kv
        h = cm.rms_norm(x, lp["ln1"])
        y, kc, vc = cm.attention_decode(lp["attn"], cfg, h, kc, vc, pos,
                                        window=window)
        x = x + y
        x = x + cm.swiglu(lp["mlp"], cm.rms_norm(x, lp["ln2"]))
        return x, (kc, vc)

    def step(carry, lp_kv):
        return jax.remat(block)(carry, lp_kv)

    x, (ks, vs) = jax.lax.scan(step, x, (params["layers"], (cache["k"], cache["v"])))
    x = cm.rms_norm(x, params["ln_f"])
    logits = cm.unembed(x, params["unembed"])[:, 0]
    return logits, {"k": ks, "v": vs, "pos": pos + 1}
