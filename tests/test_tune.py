"""`repro.tune`: MLE recovery, planner paper-anchor, trainer autotune loop.

Layered like the rest of the suite:

  1. deterministic seeded checks always run (this container has no
     hypothesis);
  2. a hypothesis property test widens the MLE round-trip when hypothesis
     is installed (CI);
  3. a real-Trainer integration slice drives the measure -> fit -> re-plan
     -> codec-swap loop end to end on the 4-worker host mesh, including
     the compile-cache reuse and partial=True interop the ISSUE requires.
"""
import dataclasses

import numpy as np
import pytest

from repro.core.runtime_model import (RuntimeParams, expected_total_runtime,
                                      optimal_triple)
import repro.coding as coding
from repro.tune import (AutotunePolicy, Autotuner, DriftingSampler,
                        FitResult, Plan, ShiftedExpSampler, StepRecord,
                        TelemetryLog, WorkerTimes, crosscheck_waits,
                        fit_runtime_params, fit_shifted_exponential,
                        rank_plans, record_from_times, step_cost_book,
                        synthetic_fit)

PAPER_N8 = RuntimeParams(n=8, lambda1=0.8, lambda2=0.1, t1=1.6, t2=6.0)


# ------------------------------------------------------------ MLE estimator
def test_shifted_exp_mle_deterministic_roundtrip():
    rng = np.random.default_rng(0)
    for t_true, lam_true in [(1.6, 0.8), (6.0, 0.1), (0.5, 2.0)]:
        x = t_true + rng.exponential(1.0 / lam_true, 4000)
        t_hat, lam_hat = fit_shifted_exponential(x)
        assert abs(t_hat - t_true) < 0.15 / lam_true + 1e-3
        assert abs(lam_hat - lam_true) / lam_true < 0.10


def test_shifted_exp_mle_rejects_tiny_samples():
    with pytest.raises(ValueError):
        fit_shifted_exponential([1.0])


def test_fit_runtime_params_recovers_ground_truth():
    """Full-pipeline round trip: sampler -> records -> fit, paper constants."""
    fit = synthetic_fit(PAPER_N8, steps=800, seed=7)
    p = fit.params
    assert abs(p.t1 - PAPER_N8.t1) / PAPER_N8.t1 < 0.10
    assert abs(p.lambda1 - PAPER_N8.lambda1) / PAPER_N8.lambda1 < 0.15
    assert abs(p.t2 - PAPER_N8.t2) / PAPER_N8.t2 < 0.10
    assert abs(p.lambda2 - PAPER_N8.lambda2) / PAPER_N8.lambda2 < 0.15
    # homogeneous ground truth -> estimated speeds hug 1
    assert fit.speed_spread < 1.15
    assert fit.n_steps == 800


def test_fit_normalises_across_mixed_schemes():
    """Records from different (d, m) pool into one consistent fit."""
    params = RuntimeParams(n=4, lambda1=0.5, lambda2=0.2, t1=0.5, t2=16.0)
    sampler = ShiftedExpSampler(params, seed=11)
    records = []
    for t in range(600):
        d, s, m = [(4, 2, 2), (3, 1, 2), (1, 0, 1)][t % 3]
        wt = sampler.draw((d,) * 4, 4, m)
        records.append(record_from_times(
            t, _FakeCode(4, d, s, m), "gather", True, wt))
    fit = fit_runtime_params(records)
    assert abs(fit.params.t2 - params.t2) / params.t2 < 0.10
    assert abs(fit.params.t1 - params.t1) / params.t1 < 0.20


def test_fit_estimates_speed_vector():
    """A 2x skewed cluster shows up in the fitted speeds."""
    params = RuntimeParams(n=4, lambda1=0.5, lambda2=0.2, t1=4.0, t2=4.0)
    speeds = np.array([0.5, 1.0, 1.0, 2.0])
    sampler = ShiftedExpSampler(params, speeds=speeds, seed=3)
    records = []
    for t in range(500):
        wt = sampler.draw((3,) * 4, 4, 2)
        records.append(record_from_times(
            t, _FakeCode(4, 3, 1, 2), "gather", True, wt))
    fit = fit_runtime_params(records)
    rel = speeds / speeds.mean()
    assert np.allclose(fit.speeds, rel, rtol=0.15)
    assert fit.speed_spread > 2.5   # true spread 4x, well past threshold


def test_crosscheck_agrees_for_exact_fit():
    """Observed mean waits match the fitted model's order-statistic E[T]."""
    params = RuntimeParams(n=4, lambda1=0.5, lambda2=0.2, t1=0.5, t2=16.0)
    sampler = ShiftedExpSampler(params, seed=5)
    records = []
    for t in range(1500):
        wt = sampler.draw((4,) * 4, 4, 2)
        records.append(record_from_times(
            t, _FakeCode(4, 4, 2, 2), "gather", True, wt))
    fit = FitResult(params=params, speeds=np.ones(4), n_steps=0, n_samples=0)
    assert crosscheck_waits(fit, records, npts=30_000) < 0.05


# ---------------------------------------------------------------- planner
def test_planner_reproduces_paper_n8_optimum():
    """Fed the paper's exact constants, the ranked search returns the
    published optimal triple (4, 1, 3) — and agrees with optimal_triple
    across the whole frontier ordering."""
    exact = FitResult(params=PAPER_N8, speeds=np.ones(8), n_steps=0,
                      n_samples=0)
    ranked = rank_plans(exact, schedules=("gather",), npts=60_000)
    top = ranked[0]
    assert (top.d, top.s, top.m) == (4, 1, 3)
    (d, s, m), best_v = optimal_triple(PAPER_N8, npts=60_000)
    assert (top.d, top.s, top.m) == (d, s, m)
    assert top.predicted_wait_s == pytest.approx(best_v, rel=1e-3)
    # every uniform candidate's wait matches the runtime model directly
    for p in ranked[:5]:
        assert p.predicted_wait_s == pytest.approx(
            expected_total_runtime(PAPER_N8, p.d, p.s, p.m, npts=60_000),
            rel=1e-6)


def test_planner_min_s_floor_and_families():
    exact = FitResult(params=PAPER_N8, speeds=np.ones(8), n_steps=0,
                      n_samples=0)
    ranked = rank_plans(exact, schedules=("gather",), npts=8_000, min_s=1)
    assert all(p.s >= 1 for p in ranked)
    # homogeneous speeds: "hetero" stays locked behind the spread threshold
    ranked = rank_plans(exact, schedules=("gather",), npts=8_000,
                        families=("uniform", "hetero"))
    assert all(p.family == "uniform" for p in ranked)
    # ... but "hetero!" forces it
    ranked = rank_plans(exact, schedules=("gather",), npts=8_000,
                        families=("hetero!",), mc_iters=50)
    assert ranked and all(p.family == "hetero" for p in ranked)
    assert all(p.s >= 1 for p in ranked)


def test_planner_pipelined_candidates_use_overlapped_model():
    """pipelined_options=(False, True) doubles the uniform frontier: each
    pipelined candidate's wait is the overlapped model (per-worker cycle
    max(comp, comm) + PIPELINE_EPS), which dominates on comm-heavy
    constants — and the sync twin of every pipelined plan keeps the plain
    E[T_tot].  The default search space stays sync-only."""
    from repro.core.runtime_model import expected_total_runtime_overlapped
    from repro.tune import PIPELINE_EPS

    exact = FitResult(params=PAPER_N8, speeds=np.ones(8), n_steps=0,
                      n_samples=0)
    assert all(not p.pipelined
               for p in rank_plans(exact, schedules=("gather",), npts=8_000))
    ranked = rank_plans(exact, schedules=("gather",), npts=8_000,
                        pipelined_options=(False, True))
    assert {p.pipelined for p in ranked} == {False, True}
    top = ranked[0]
    assert top.pipelined   # overlap always wins on the modeled wait alone
    assert "pipelined" in top.describe()
    for p in ranked:
        want = (expected_total_runtime_overlapped(
                    PAPER_N8, p.d, p.s, p.m, npts=8_000, eps=PIPELINE_EPS)
                if p.pipelined
                else expected_total_runtime(PAPER_N8, p.d, p.s, p.m,
                                            npts=8_000))
        assert p.predicted_wait_s == pytest.approx(want, rel=1e-6)
    # scheme_key separates the twins (the trainer caches per signature)
    keys = {p.scheme_key for p in ranked}
    assert len(keys) == len(ranked)
    # hetero stays synchronous: pipelining is a uniform-family knob
    hranked = rank_plans(exact, schedules=("gather",), npts=8_000,
                         families=("hetero!",), mc_iters=30,
                         pipelined_options=(False, True))
    assert hranked and all(not p.pipelined for p in hranked)


def test_step_cost_book_keys_on_pipelined():
    """A pipelined steady-state measurement must not calibrate the sync
    twin (and vice versa): the book keys per (schedule, packed, pipelined)."""
    recs = [
        StepRecord(step=0, d=3, s=1, m=2, k=4, loads=(3,) * 4,
                   schedule="gather", packed=True, compute_s=np.zeros(4),
                   comm_s=np.zeros(4), measured_step_s=3.0),
        StepRecord(step=1, d=3, s=1, m=2, k=4, loads=(3,) * 4,
                   schedule="gather", packed=True, compute_s=np.zeros(4),
                   comm_s=np.zeros(4), measured_step_s=1.0, pipelined=True),
    ]
    book = step_cost_book(recs)
    assert book.cost(3, 4, (3,) * 4, "gather", True) == pytest.approx(3.0)
    assert book.cost(3, 4, (3,) * 4, "gather", True,
                     pipelined=True) == pytest.approx(1.0)


def test_planner_step_cost_calibration_breaks_ties():
    """Measured step costs reorder schedules with identical modeled waits."""
    exact = FitResult(params=PAPER_N8, speeds=np.ones(8), n_steps=0,
                      n_samples=0)
    recs = [
        StepRecord(step=0, d=3, s=1, m=2, k=8, loads=(3,) * 8,
                   schedule="gather", packed=True, compute_s=np.zeros(8),
                   comm_s=np.zeros(8), measured_step_s=5.0),
        StepRecord(step=1, d=3, s=1, m=2, k=8, loads=(3,) * 8,
                   schedule="a2a", packed=True, compute_s=np.zeros(8),
                   comm_s=np.zeros(8), measured_step_s=0.010),
    ]
    ranked = rank_plans(exact, schedules=("gather", "a2a"), npts=8_000,
                        cost_book=step_cost_book(recs))
    assert ranked[0].schedule == "a2a"
    assert 0 < ranked[0].predicted_step_s < 1.0


def test_step_cost_book_exact_and_load_scaled_fallback():
    recs = []
    for i, (sched, d, wall) in enumerate([("gather", 3, 1.0),
                                          ("gather", 3, 3.0),
                                          ("a2a", 2, 2.0),
                                          ("a2a", 2, 0.0)]):
        recs.append(StepRecord(
            step=i, d=d, s=1, m=1, k=4, loads=(d,) * 4, schedule=sched,
            packed=True, compute_s=np.zeros(4), comm_s=np.zeros(4),
            measured_step_s=wall))
    book = step_cost_book(recs)
    assert len(book) == 2   # zero-wall record contributes nothing new
    # exact scheme hit: the mean of its own measurements
    assert book.cost(3, 4, (3,) * 4, "gather", True) == pytest.approx(2.0)
    assert book.cost(2, 4, (2,) * 4, "a2a", True) == pytest.approx(2.0)
    # unseen d, known config: per-load mean (2.0/3) scaled by the new d —
    # a d=1 candidate is NOT charged the d=3 step's wall-clock
    assert book.cost(1, 4, (1,) * 4, "gather", True) == pytest.approx(2 / 3)
    # unseen config: global per-load mean ((1/3 + 3/3 + 2/2) / 3) * d
    assert book.cost(1, 4, (1,) * 4, "psum", True) == pytest.approx(
        (1 / 3 + 1.0 + 1.0) / 3)
    # empty book: free
    from repro.tune import StepCostBook
    assert StepCostBook().cost(4, 4, (4,) * 4, "gather", True) == 0.0


# ------------------------------------------------------- telemetry plumbing
class _FakeCode:
    """Minimal GradCode duck for telemetry/estimator unit tests."""

    def __init__(self, n, d, s, m, k=None, loads=None):
        self.n, self.d, self.s, self.m = n, d, s, m
        self.num_subsets = k if k is not None else n
        self.loads = tuple(loads) if loads is not None else (d,) * n


def test_worker_times_order_stat():
    wt = WorkerTimes(compute_s=np.array([1.0, 5.0, 2.0, 9.0]),
                     comm_s=np.array([0.5, 0.5, 0.5, 0.5]))
    slow, wait = wt.order_stat(1)
    assert slow == (3,)
    assert wait == pytest.approx(5.5)
    none, wait_all = wt.order_stat(0)
    assert none == () and wait_all == pytest.approx(9.5)


def test_telemetry_log_capacity_and_window():
    log = TelemetryLog(capacity=10)
    for t in range(25):
        log.append(StepRecord(
            step=t, d=3, s=1, m=2, k=4, loads=(3,) * 4, schedule="gather",
            packed=True, compute_s=np.zeros(4), comm_s=np.zeros(4)))
    assert len(log) == 10
    assert [r.step for r in log.window(3)] == [22, 23, 24]
    assert log.records[0].step == 15


def test_drifting_sampler_phases():
    pA = RuntimeParams(n=4, lambda1=1.0, lambda2=1.0, t1=1.0, t2=1.0)
    pB = RuntimeParams(n=4, lambda1=1.0, lambda2=1.0, t1=50.0, t2=1.0)
    drift = DriftingSampler([(0, pA), (10, pB)], seed=0)
    assert drift.params_at(0) is pA and drift.params_at(9) is pA
    assert drift.params_at(10) is pB
    code = _FakeCode(4, 2, 1, 1)
    early = drift(0, code)
    late = drift(12, code)
    assert early.compute_s.max() < 50.0 <= late.compute_s.min()
    with pytest.raises(ValueError):
        DriftingSampler([(10, pA), (0, pB)])


# ------------------------------------------------------------ control loop
def _mk_plan(d, s, m, schedule="gather"):
    return Plan(family="uniform", d=d, s=s, m=m, k=4, loads=(d,) * 4,
                schedule=schedule, packed=True, predicted_wait_s=0.0,
                predicted_step_s=0.0, predicted_total_s=0.0)


def test_autotuner_holds_then_switches_under_drift():
    pA = RuntimeParams(n=4, lambda1=0.5, lambda2=0.2, t1=0.5, t2=16.0)
    pB = RuntimeParams(n=4, lambda1=0.5, lambda2=0.2, t1=16.0, t2=0.5)
    policy = AutotunePolicy(interval=5, window=10, min_samples=5,
                            schedules=("gather",), npts=6_000)
    tuner = Autotuner(policy, current=_mk_plan(4, 2, 2))
    drift = DriftingSampler([(0, pA), (20, pB)], seed=9)
    code = _FakeCode(4, 4, 2, 2)
    switched_at = None
    for t in range(40):
        wt = drift(t, code)
        tuner.record(record_from_times(t, code, "gather", True, wt))
        new = tuner.maybe_replan(t)
        if new is not None:
            switched_at = t
            code = _FakeCode(4, new.d, new.s, new.m)
    # held the optimum through phase A, moved off it after the drift
    assert switched_at is not None and switched_at >= 20
    assert (code.d, code.s, code.m) != (4, 2, 2)
    assert any(e["switched"] for e in tuner.events)
    holds = [e for e in tuner.events if not e["switched"]]
    assert holds and all(e["current_predicted_s"] is not None
                         for e in holds)


def test_autotuner_rejects_implausible_fit():
    """A fit whose cross-check error exceeds the policy bound must not
    drive a switch (the documented refusal)."""
    params = RuntimeParams(n=4, lambda1=0.5, lambda2=0.2, t1=0.5, t2=16.0)
    policy = AutotunePolicy(interval=4, window=8, min_samples=4,
                            schedules=("gather",), npts=4_000,
                            max_crosscheck_rel_err=0.0)   # reject everything
    tuner = Autotuner(policy, current=_mk_plan(4, 2, 2))
    sampler = ShiftedExpSampler(params, seed=1)
    code = _FakeCode(4, 4, 2, 2)
    for t in range(12):
        tuner.record(record_from_times(t, code, "gather", True,
                                       sampler(t, code)))
        assert tuner.maybe_replan(t) is None
    rejected = [e for e in tuner.events if e.get("rejected_fit")]
    assert rejected and all(not e["switched"] for e in tuner.events)
    # rejected events keep the full key set so consumers index uniformly
    assert all(e["best"] is None and e["current_predicted_s"] is None
               for e in rejected)
    assert tuner.current.scheme_key == _mk_plan(4, 2, 2).scheme_key


def test_autotuner_rescorees_current_outside_search_space():
    """An active plan absent from the ranking (schedule not searched) is
    re-scored for the hysteresis comparison — never auto-switched."""
    params = RuntimeParams(n=4, lambda1=0.5, lambda2=0.2, t1=0.5, t2=16.0)
    policy = AutotunePolicy(interval=4, window=8, min_samples=4,
                            schedules=("gather",), npts=6_000)
    # active: the optimal triple but on a schedule the policy won't search;
    # the ranked gather twin has the same modeled wait, so hysteresis must
    # hold rather than flap onto it
    tuner = Autotuner(policy, current=_mk_plan(4, 2, 2, schedule="a2a"))
    sampler = ShiftedExpSampler(params, seed=2)
    code = _FakeCode(4, 4, 2, 2)
    for t in range(8):
        tuner.record(record_from_times(t, code, "gather", True,
                                       sampler(t, code)))
        assert tuner.maybe_replan(t) is None
    assert tuner.current.schedule == "a2a"   # held
    scored = [e for e in tuner.events if "current_predicted_s" in e]
    assert scored and all(e["current_predicted_s"] is not None
                          and e["current_predicted_s"] > 0 for e in scored)


def test_autotuner_not_due_before_min_samples():
    policy = AutotunePolicy(interval=2, window=8, min_samples=6)
    tuner = Autotuner(policy, current=_mk_plan(3, 1, 2))
    sampler = ShiftedExpSampler(
        RuntimeParams(n=4, lambda1=1.0, lambda2=1.0, t1=1.0, t2=1.0), seed=0)
    code = _FakeCode(4, 3, 1, 2)
    for t in range(5):
        tuner.record(record_from_times(t, code, "gather", True,
                                       sampler(t, code)))
        assert not tuner.due()
        assert tuner.maybe_replan(t) is None
    tuner.record(record_from_times(5, code, "gather", True,
                                   sampler(5, code)))
    assert tuner.due()


# ------------------------------------------------- hypothesis widening (CI)
try:
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=15, deadline=None)
    @given(st.floats(0.2, 8.0), st.floats(0.1, 2.0),
           st.floats(0.2, 20.0), st.floats(0.05, 1.0),
           st.integers(0, 2**31 - 1))
    def test_mle_roundtrip_property(t1, lam1, t2, lam2, seed):
        """The acceptance-criterion property: the shifted-exponential MLE
        recovers (t1, l1, t2, l2) within tolerance on synthetic draws."""
        params = RuntimeParams(n=6, lambda1=lam1, lambda2=lam2, t1=t1, t2=t2)
        fit = synthetic_fit(params, steps=500, seed=seed, probe=(2, 1, 1))
        p = fit.params
        assert abs(p.t1 - t1) <= 0.25 / lam1 + 0.02 * t1
        assert abs(p.lambda1 - lam1) / lam1 < 0.25
        assert abs(p.t2 - t2) <= 0.25 / lam2 + 0.02 * t2
        assert abs(p.lambda2 - lam2) / lam2 < 0.25
except ImportError:  # hypothesis optional at runtime (declared in [test])
    pass


# ------------------------------------------------ trainer integration (e2e)
def test_trainer_autotune_swaps_codec_and_reuses_cache():
    """The tentpole loop on the real jitted step: telemetry -> fit ->
    re-plan -> codec swap, with compile-cache reuse on the way back."""
    from repro.configs import get_config
    from repro.core import make_code
    from repro.data import make_synthetic_batch
    from repro.launch.mesh import make_local_mesh
    from repro.optim import get_optimizer
    from repro.train import Trainer

    pA = RuntimeParams(n=4, lambda1=0.5, lambda2=0.2, t1=0.5, t2=16.0)
    pB = RuntimeParams(n=4, lambda1=0.5, lambda2=0.2, t1=16.0, t2=0.5)
    drift = DriftingSampler([(0, pA), (6, pB)], seed=3)
    cfg = dataclasses.replace(get_config("logistic-paper"), d_model=64)
    policy = AutotunePolicy(interval=3, window=6, min_samples=3,
                            schedules=("gather",), npts=4_000)
    tr = Trainer(cfg, make_code(4, 4, 2, 2), make_local_mesh(4, 1),
                 optimizer=get_optimizer("sgd", 1e-2),
                 straggler_source=drift, autotune=policy)
    rng = np.random.default_rng(0)
    for i in range(16):
        m = tr.step(make_synthetic_batch(rng, cfg, 16, 0))
        assert "modeled_wait_s" in m and "step_time_s" in m
    assert any(e["switched"] for e in tr.autotune_events)
    assert (tr.code.d, tr.code.s, tr.code.m) != (4, 2, 2)
    assert len(tr.telemetry) == 16
    n_arts = len(tr._arts_cache)
    n_jit = len(tr._jitted)
    assert n_arts >= 2
    # force a swap back to the original scheme: both caches must be reused
    tr._apply_plan(_mk_plan(4, 2, 2))
    tr.step(make_synthetic_batch(rng, cfg, 16, 0))
    assert len(tr._arts_cache) == n_arts
    assert len(tr._jitted) == n_jit


def test_step_artifacts_instrumented_reports_time():
    """The coded_step telemetry hook: blocked wall-clock per call."""
    import jax
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.core import make_code
    from repro.data import CodedBatcher, make_synthetic_batch
    from repro.launch.mesh import make_local_mesh
    from repro.optim import get_optimizer
    from repro.train import make_coded_train_step

    cfg = dataclasses.replace(get_config("logistic-paper"), d_model=64)
    code = make_code(4, 3, 1, 2)
    opt = get_optimizer("sgd", 1e-2)
    arts = make_coded_train_step(cfg, code, make_local_mesh(4, 1), opt)
    rng = np.random.default_rng(0)
    placed = jax.tree.map(
        jnp.asarray, CodedBatcher(code).place(
            make_synthetic_batch(rng, cfg, 16, 0)))
    from repro.models import api as model_api
    params = model_api.init(jax.random.PRNGKey(0), cfg)
    walls = []
    timed = arts.instrumented(placed, walls.append)
    inp = arts.step_inputs(())
    out = timed(params, opt.init(params), placed,
                inp["W"], inp["mask"], inp["rho"])
    assert len(out) == 3 and "loss" in out[2]
    assert len(walls) == 1 and walls[0] > 0


def test_trainer_injector_conflicts_with_straggler_mode():
    from repro.configs import get_config
    from repro.core import make_code
    from repro.launch.mesh import make_local_mesh
    from repro.optim import get_optimizer
    from repro.train import Trainer

    cfg = dataclasses.replace(get_config("logistic-paper"), d_model=64)
    sampler = ShiftedExpSampler(
        RuntimeParams(n=4, lambda1=1.0, lambda2=1.0, t1=1.0, t2=1.0))
    with pytest.raises(ValueError, match="injector"):
        Trainer(cfg, make_code(4, 3, 1, 2), make_local_mesh(4, 1),
                optimizer=get_optimizer("sgd", 1e-2),
                straggler_mode="random", injector=sampler)


def test_trainer_autotune_requires_injector():
    from repro.configs import get_config
    from repro.core import make_code
    from repro.launch.mesh import make_local_mesh
    from repro.optim import get_optimizer
    from repro.train import Trainer

    cfg = dataclasses.replace(get_config("logistic-paper"), d_model=64)
    with pytest.raises(ValueError, match="injector"):
        Trainer(cfg, make_code(4, 3, 1, 2), make_local_mesh(4, 1),
                optimizer=get_optimizer("sgd", 1e-2),
                autotune=AutotunePolicy())


def test_trainer_autotune_partial_interop():
    """partial=True survives codec swaps: every cached artifact is built in
    partial mode and the step keeps emitting the error-bound metric."""
    from repro.configs import get_config
    from repro.core import make_code
    from repro.data import make_synthetic_batch
    from repro.launch.mesh import make_local_mesh
    from repro.optim import get_optimizer
    from repro.train import Trainer

    pA = RuntimeParams(n=4, lambda1=0.5, lambda2=0.2, t1=0.5, t2=16.0)
    pB = RuntimeParams(n=4, lambda1=0.5, lambda2=0.2, t1=16.0, t2=0.5)
    drift = DriftingSampler([(0, pA), (4, pB)], seed=6)
    cfg = dataclasses.replace(get_config("logistic-paper"), d_model=64)
    policy = AutotunePolicy(interval=3, window=6, min_samples=3,
                            schedules=("gather",), npts=4_000)
    tr = Trainer(cfg, make_code(4, 4, 2, 2), make_local_mesh(4, 1),
                 optimizer=get_optimizer("sgd", 1e-2),
                 spec=coding.SchemeSpec(partial=True),
                 straggler_source=drift, autotune=policy)
    rng = np.random.default_rng(1)
    for i in range(10):
        m = tr.step(make_synthetic_batch(rng, cfg, 16, 0))
        assert "decode_err_bound" in m
        assert np.isfinite(m["decode_err_bound"])
    assert any(e["switched"] for e in tr.autotune_events)
    assert all(k[3] is True for k in tr._arts_cache)  # partial flag in key
