from .engine import build_serve_artifacts, ServeArtifacts

__all__ = ["build_serve_artifacts", "ServeArtifacts"]
