"""Loop-aware HLO cost analysis.

XLA's ``compiled.cost_analysis()`` counts every computation ONCE — a scan
(while) body's FLOPs are not multiplied by the trip count, which silently
undercounts scan-over-layers models by ~L x and hides collectives inside
scanned layers (e.g. GSPMD all-to-alls in a scanned MoE block).  This module
re-derives the roofline inputs from ``compiled.as_text()``:

- parses computations + the call graph (while/fusion/call/cond),
- multiplies by ``backend_config={"known_trip_count": ...}`` for whiles,
- FLOPs from ``dot`` ops (2 * prod(out) * prod(contracting dims)),
- HBM-ish bytes from fusion/dot/copy/collective operand+result sizes,
- collective bytes bucketed by op kind.

This is textual analysis — shapes and call structure are exact; the bytes
term approximates "each op reads its operands and writes its result once".
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8, "s32": 4,
    "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1, "f8": 1,
    "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|f8\w*|s64|u64|s32|u32|s16|u16|s8|u8|s4|u4|pred)\[([\d,]*)\]")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\(")
_CALL_RE = re.compile(r"(?:body|condition|to_apply|calls|branch_computations)=\{?%?([\w.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count[\\\"={:]+n[\\\"]*:?[\\\"]*(\d+)')
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_BATCH_RE = re.compile(r"lhs_batch_dims=\{([\d,]*)\}")
_OPERANDS_RE = re.compile(r"%([\w.\-]+)")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute", "ragged-all-to-all")


def _shape_bytes_elems(type_str: str) -> tuple[int, int]:
    """Total (bytes, elements) over every array in a (possibly tuple) type."""
    bts = elems = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        b = _DTYPE_BYTES.get(dt, 1 if dt.startswith("f8") else 4)
        bts += n * b
        elems += n
    return bts, elems


def _shape_dims(type_str: str) -> list[int]:
    m = _SHAPE_RE.search(type_str)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",")]


@dataclasses.dataclass
class OpInfo:
    name: str
    result_type: str
    opcode: str
    line: str
    callees: list[str]


@dataclasses.dataclass
class Computation:
    name: str
    ops: list[OpInfo]
    defs: dict[str, str]     # op name -> result type string


def parse_computations(text: str) -> tuple[dict[str, Computation], str]:
    comps: dict[str, Computation] = {}
    entry = ""
    cur: Computation | None = None
    for line in text.splitlines():
        if line.startswith("HloModule"):
            continue
        if not line.startswith(" ") and "{" in line and ("->" in line or line.startswith("ENTRY")):
            is_entry = line.startswith("ENTRY")
            name_m = re.match(r"(?:ENTRY\s+)?%?([\w.\-]+)", line)
            if name_m:
                cur = Computation(name_m.group(1), [], {})
                comps[cur.name] = cur
                if is_entry:
                    entry = cur.name
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _OP_RE.match(line)
        if not m:
            # parameters: "%p = f32[..] parameter(0)" matches _OP_RE; others skip
            continue
        name, rtype, opcode = m.group(1), m.group(2), m.group(3)
        callees = _CALL_RE.findall(line)
        cur.ops.append(OpInfo(name, rtype, opcode, line, callees))
        cur.defs[name] = rtype
    return comps, entry


def _multipliers(comps: dict[str, Computation], entry: str
                 ) -> tuple[dict[str, float], set[str]]:
    """Call-count multiplier per computation + the set of computations that
    live inside a fusion body (register-level — their ops do not touch HBM)."""
    mult: dict[str, float] = defaultdict(float)
    mult[entry] = 1.0
    fused: set[str] = set()
    pending = [entry]
    while pending:
        cname = pending.pop()
        cm = mult[cname]
        comp = comps.get(cname)
        if comp is None:
            continue
        for op in comp.ops:
            if not op.callees:
                continue
            factor = 1.0
            if op.opcode == "while":
                tm = _TRIP_RE.search(op.line)
                factor = float(tm.group(1)) if tm else 1.0
            inside_fusion = (op.opcode in ("fusion", "reduce", "map", "sort",
                                           "scatter", "reduce-window")
                             or cname in fused)
            for callee in op.callees:
                if callee in comps:
                    before = mult[callee]
                    mult[callee] += cm * factor
                    if inside_fusion:
                        fused.add(callee)
                    if mult[callee] != before or (inside_fusion
                                                  and callee not in fused):
                        pending.append(callee)
    # propagate fusion membership transitively
    changed = True
    while changed:
        changed = False
        for cname, comp in comps.items():
            if cname not in fused:
                continue
            for op in comp.ops:
                for callee in op.callees:
                    if callee in comps and callee not in fused:
                        fused.add(callee)
                        changed = True
    return dict(mult), fused


def _dot_flops(op: OpInfo, comp: Computation) -> float:
    out_dims = _shape_dims(op.result_type)
    out_elems = 1
    for d in out_dims:
        out_elems *= d
    cm = _CONTRACT_RE.search(op.line)
    # operands appear after the opcode paren
    tail = op.line.split(op.opcode + "(", 1)[1]
    operand_names = _OPERANDS_RE.findall(tail.split(")")[0])
    contract = 1
    if cm and operand_names:
        lhs_type = comp.defs.get(operand_names[0], "")
        lhs_dims = _shape_dims(lhs_type)
        if cm.group(1):
            for idx in cm.group(1).split(","):
                i = int(idx)
                if i < len(lhs_dims):
                    contract *= lhs_dims[i]
    return 2.0 * out_elems * contract


_BYTES_OPS = ("fusion", "dot", "copy", "convolution", "scatter", "gather",
              "dynamic-update-slice", "dynamic-slice", "reduce", "transpose",
              "broadcast", "iota", "compare", "select", "add", "multiply",
              "subtract", "divide", "exponential", "tanh", "convert", "sort",
              "concatenate", "reshape", "slice", "pad", "reverse", "rsqrt",
              "log", "maximum", "minimum", "negate", "power", "sqrt",
              "reduce-window", "map", "clamp", "and", "or", "xor", "not")

# ops that read only an output-sized window of their (possibly huge) operand
_SLICE_LIKE = ("dynamic-slice", "slice", "gather")


def _operand_names(op: OpInfo) -> list[str]:
    tail = op.line.split(op.opcode + "(", 1)
    if len(tail) != 2:
        return []
    return _OPERANDS_RE.findall(tail[1].split(")")[0])


def _param_slice_bytes(comps: dict[str, Computation], callee: str,
                       k: int) -> float | None:
    """Bytes actually read from parameter k of ``callee``:
    - consumed only by slice-like ops -> summed consumer-output bytes,
    - consumed as the *target buffer* (operand 0) of a dynamic-update-slice
      -> 0 (aliased in-place write; only the window moves),
    - anything else -> None (full operand is read)."""
    comp = comps.get(callee)
    if comp is None:
        return None
    pname = None
    for op in comp.ops:
        if op.opcode == "parameter" and f"parameter({k})" in op.line:
            pname = op.name
            break
    if pname is None:
        return None
    total = 0.0
    for op in comp.ops:
        if op.opcode == "parameter":
            continue
        if f"%{pname}" in op.line.split("=", 1)[-1]:
            if op.opcode in _SLICE_LIKE:
                b, _ = _shape_bytes_elems(op.result_type)
                total += b
            elif op.opcode == "dynamic-update-slice":
                ops_ = _operand_names(op)
                if ops_ and ops_[0] == pname:
                    continue                       # aliased target buffer
                return None
            else:
                return None
    return total


def _follow(comp: Computation, name: str, depth: int = 4) -> OpInfo | None:
    """Follow bitcast/reshape/copy chains to the producing op."""
    by_name = {op.name: op for op in comp.ops}
    op = by_name.get(name)
    for _ in range(depth):
        if op is None or op.opcode not in ("bitcast", "reshape", "copy",
                                           "transpose", "convert"):
            return op
        ops_ = _operand_names(op)
        op = by_name.get(ops_[0]) if ops_ else None
    return op


def _fusion_output_bytes(comps: dict[str, Computation], callee: str,
                         out_b: float) -> float:
    """If the fusion's root is a dynamic-update-slice (possibly behind a
    bitcast), only the update window is written, not the whole buffer."""
    comp = comps.get(callee)
    if comp is None:
        return out_b
    root = next((op for op in comp.ops if "ROOT" in op.line.split("=", 1)[0]
                 or op.line.lstrip().startswith("ROOT")), None)
    if root is None:
        return out_b
    op = root
    if op.opcode in ("bitcast", "reshape", "copy", "transpose", "convert"):
        ops_ = _operand_names(op)
        op = _follow(comp, ops_[0]) if ops_ else None
    if op is not None and op.opcode == "dynamic-update-slice":
        ops_ = _operand_names(op)
        if len(ops_) > 1:
            src = _follow(comp, ops_[1])
            ub, _ = _shape_bytes_elems(
                comp.defs.get(src.name if src else ops_[1], ""))
            if ub:
                return ub
    return out_b


def _op_bytes(op: OpInfo, comp: Computation,
              comps: dict[str, Computation]) -> float:
    out_b, _ = _shape_bytes_elems(op.result_type)
    operands = _operand_names(op)
    if op.opcode in _SLICE_LIKE:
        return 2.0 * out_b                      # read a window, write it
    if op.opcode == "dynamic-update-slice":
        upd = operands[1] if len(operands) > 1 else None
        ub, _ = _shape_bytes_elems(comp.defs.get(upd, "")) if upd else (out_b, 0)
        return 2.0 * ub                         # read update, write window
    if op.opcode == "fusion":
        callee = op.callees[0] if op.callees else None
        total = _fusion_output_bytes(comps, callee, out_b) if callee else out_b
        for k, nm in enumerate(operands):
            full, _ = _shape_bytes_elems(comp.defs.get(nm, ""))
            sliced = _param_slice_bytes(comps, callee, k) if callee else None
            total += min(full, sliced) if sliced is not None else full
        return total
    in_b = 0.0
    for nm in operands:
        ib, _ = _shape_bytes_elems(comp.defs.get(nm, ""))
        in_b += ib
    return out_b + in_b


def analyze(text: str) -> dict:
    comps, entry = parse_computations(text)
    mult, fused = _multipliers(comps, entry)
    flops = 0.0
    bytes_accessed = 0.0
    coll: dict[str, float] = defaultdict(float)
    coll_count: dict[str, int] = defaultdict(int)
    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        in_fusion = cname in fused
        for op in comp.ops:
            oc = op.opcode
            if oc == "dot":
                flops += m * _dot_flops(op, comp)   # FLOPs count even in fusions
            if in_fusion:
                continue                            # register traffic, not HBM
            if oc in COLLECTIVES:
                b, _ = _shape_bytes_elems(op.result_type)
                coll[oc] += m * b
                coll_count[oc] += int(m)
            if oc in _BYTES_OPS or oc in COLLECTIVES:
                bytes_accessed += m * _op_bytes(op, comp, comps)
    return {
        "flops": flops,
        "bytes_accessed": bytes_accessed,
        "collective_bytes": dict(coll),
        "collective_counts": dict(coll_count),
        "n_computations": len(comps),
    }
