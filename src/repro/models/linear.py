"""The paper's own workload (Section V): l2-regularized logistic regression.
batch: {"x": (B, l) features, "y": (B,) in {0,1}}."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import common as cm


def init(key, cfg):
    dt = cm.pdtype(cfg)
    return {"beta": jnp.zeros((cfg.d_model,), dt)}


def logits(params, cfg, x):
    return jnp.einsum("bl,l->b", x.astype(jnp.float32),
                      params["beta"].astype(jnp.float32))


def loss(params, cfg, batch, l2: float = 0.0):
    z = logits(params, cfg, batch["x"])
    y = batch["y"].astype(jnp.float32)
    # sum (not mean): the paper's gradient is a sum over samples, which is
    # what the coded aggregation reconstructs exactly.
    nll = jnp.sum(jax.nn.softplus(z) - y * z)
    if l2:
        nll = nll + 0.5 * l2 * jnp.sum(params["beta"].astype(jnp.float32) ** 2)
    return nll


def predict_proba(params, cfg, x):
    return jax.nn.sigmoid(logits(params, cfg, x))
