"""SchemeSpec: the one value object for every scheme lever.

Pins the API-redesign contracts: the spec path and the deprecated kwarg
path build bitwise-identical steps; spec= and kwargs cannot be mixed; the
spec's validation reproduces the historical error messages; and the
Trainer's legacy straggler fields map onto the StragglerSource protocol
with deprecation warnings.
"""
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.coding as coding
from repro.configs import get_config
from repro.core import make_code
from repro.data import CodedBatcher, make_synthetic_batch
from repro.launch.mesh import make_local_mesh
from repro.models import api as model_api
from repro.optim import get_optimizer
from repro.train.coded_step import make_coded_train_step
from repro.train.trainer import Trainer
from repro.tune import (FixedStragglers, NoStragglers, RandomStragglers,
                        StragglerSource, TimedSource, as_straggler_source)

CODE = make_code(4, 3, 1, 2)


def _linear_cfg():
    return dataclasses.replace(get_config("logistic-paper"), d_model=64)


# ----------------------------------------------------------- the value object
def test_spec_is_frozen_and_replace_works():
    spec = coding.SchemeSpec(schedule="a2a", packed=False)
    with pytest.raises(dataclasses.FrozenInstanceError):
        spec.schedule = "gather"
    spec2 = spec.replace(packed=True)
    assert spec2.schedule == "a2a" and spec2.packed and not spec.packed


def test_spec_validation_reproduces_historical_messages():
    with pytest.raises(ValueError, match="packed"):
        coding.SchemeSpec(pipelined=True, packed=False)
    with pytest.raises(ValueError, match="partial"):
        coding.SchemeSpec(pipelined=True, partial=True)
    with pytest.raises(ValueError, match="encoding"):
        coding.SchemeSpec(pipelined=True, schedule="psum")
    with pytest.raises(ValueError, match="pipelined"):
        coding.SchemeSpec(fuse_apply=True)


def test_spec_and_kwargs_cannot_mix():
    cfg = _linear_cfg()
    mesh = make_local_mesh(4, 1)
    opt = get_optimizer("sgd", 1e-2)
    with pytest.raises(TypeError, match="not both"):
        make_coded_train_step(cfg, CODE, mesh, opt,
                              spec=coding.SchemeSpec(), schedule="a2a")


def _run_one_step(arts):
    cfg = _linear_cfg()
    rng = np.random.default_rng(5)
    batch = make_synthetic_batch(rng, cfg, 16, 0)
    placed = jax.tree.map(jnp.asarray, CodedBatcher(CODE).place(batch))
    fn = arts.compiled(placed)
    params = model_api.init(jax.random.PRNGKey(7), cfg)
    opt = get_optimizer("sgd", 1e-2)
    inp = arts.step_inputs([2])
    return fn(params, opt.init(params), placed, inp["W"], inp["mask"],
              inp["rho"])


def test_legacy_kwargs_build_bitwise_identical_step():
    """Acceptance criterion: the deprecation-shim path and the spec path
    produce bitwise-identical StepArtifacts outputs."""
    cfg = _linear_cfg()
    mesh = make_local_mesh(4, 1)
    opt = get_optimizer("sgd", 1e-2)
    spec = coding.SchemeSpec(schedule="a2a", backend="ref", packed=False,
                             encode_dtype="bfloat16")
    via_spec = make_coded_train_step(cfg, CODE, mesh, opt, spec=spec)
    with pytest.warns(DeprecationWarning, match="scheme kwargs"):
        via_kwargs = make_coded_train_step(
            cfg, CODE, mesh, opt, schedule="a2a", backend="ref",
            packed=False, encode_dtype="bfloat16")
    assert via_kwargs.spec == spec
    p_a, o_a, m_a = _run_one_step(via_spec)
    p_b, o_b, m_b = _run_one_step(via_kwargs)
    for xa, xb in zip(jax.tree.leaves((p_a, o_a, m_a)),
                      jax.tree.leaves((p_b, o_b, m_b))):
        np.testing.assert_array_equal(np.asarray(xa), np.asarray(xb))


def test_spec_threads_through_step_artifacts():
    cfg = _linear_cfg()
    mesh = make_local_mesh(4, 1)
    opt = get_optimizer("sgd", 1e-2)
    spec = coding.SchemeSpec(schedule="gather", backend="ref")
    arts = make_coded_train_step(cfg, CODE, mesh, opt, spec=spec)
    assert arts.spec is spec
    assert arts.codec.backend.name == "ref"


# -------------------------------------------------------- straggler sources
def test_as_straggler_source_dispatch():
    assert isinstance(as_straggler_source(None), NoStragglers)
    src = FixedStragglers((1, 2))
    assert as_straggler_source(src) is src
    assert isinstance(src, StragglerSource)
    timed = as_straggler_source(lambda step, code: None)
    assert isinstance(timed, TimedSource) and timed.provides_times
    with pytest.raises(TypeError):
        as_straggler_source(42)


def test_fixed_and_random_sources_draw_within_design():
    fixed = FixedStragglers((2,))
    d = fixed.draw(0, CODE)
    assert d.stragglers == (2,) and d.times is None
    rnd = RandomStragglers(seed=1)
    seen = set()
    for t in range(32):
        st = rnd.draw(t, CODE).stragglers
        assert len(st) <= CODE.s
        seen.add(st)
    assert len(seen) > 1               # actually random
    # deterministic across instances with one seed
    a = [RandomStragglers(seed=9).draw(t, CODE).stragglers
         for t in range(8)]
    b = [RandomStragglers(seed=9).draw(t, CODE).stragglers
         for t in range(8)]
    assert a == b


def test_trainer_legacy_straggler_fields_warn_and_map():
    cfg = _linear_cfg()
    mesh = make_local_mesh(4, 1)
    opt = get_optimizer("sgd", 1e-2)
    with pytest.warns(DeprecationWarning, match="straggler_source"):
        tr = Trainer(cfg=cfg, code=CODE, mesh=mesh, optimizer=opt,
                     straggler_mode="fixed", fixed_stragglers=(1,))
    assert isinstance(tr._source, FixedStragglers)
    assert tr._source.draw(0, CODE).stragglers == (1,)
    with pytest.warns(DeprecationWarning, match="straggler_source"):
        tr = Trainer(cfg=cfg, code=CODE, mesh=mesh, optimizer=opt,
                     straggler_mode="random", seed=3)
    assert isinstance(tr._source, RandomStragglers)
    tr = Trainer(cfg=cfg, code=CODE, mesh=mesh, optimizer=opt)
    assert isinstance(tr._source, NoStragglers)


def test_trainer_rejects_source_plus_legacy_fields():
    cfg = _linear_cfg()
    mesh = make_local_mesh(4, 1)
    opt = get_optimizer("sgd", 1e-2)
    with pytest.raises(ValueError, match="straggler_source"):
        Trainer(cfg=cfg, code=CODE, mesh=mesh, optimizer=opt,
                straggler_source=NoStragglers(), straggler_mode="random")
    with pytest.raises(ValueError, match="straggler"):
        Trainer(cfg=cfg, code=CODE, mesh=mesh, optimizer=opt,
                straggler_mode="nope")


def test_trainer_spec_kwarg_and_legacy_kwargs():
    cfg = _linear_cfg()
    mesh = make_local_mesh(4, 1)
    opt = get_optimizer("sgd", 1e-2)
    spec = coding.SchemeSpec(schedule="a2a", backend="ref")
    tr = Trainer(cfg=cfg, code=CODE, mesh=mesh, optimizer=opt, spec=spec)
    assert tr.spec == spec and tr.schedule == "a2a"
    with pytest.warns(DeprecationWarning, match="scheme kwargs"):
        tr2 = Trainer(cfg=cfg, code=CODE, mesh=mesh, optimizer=opt,
                      schedule="a2a", backend="ref")
    assert tr2.spec == spec
    with pytest.raises(TypeError, match="not both"):
        Trainer(cfg=cfg, code=CODE, mesh=mesh, optimizer=opt, spec=spec,
                schedule="gather")


def test_trainer_runs_one_step_from_spec():
    """The spec-built Trainer trains: one real step on the host mesh with
    a warning-free construction."""
    cfg = _linear_cfg()
    mesh = make_local_mesh(4, 1)
    opt = get_optimizer("sgd", 1e-2)
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        tr = Trainer(cfg=cfg, code=CODE, mesh=mesh, optimizer=opt,
                     spec=coding.SchemeSpec(schedule="gather"),
                     straggler_source=FixedStragglers((2,)))
    rng = np.random.default_rng(5)
    batch = make_synthetic_batch(rng, cfg, 16, 0)
    metrics = tr.step(batch)
    assert np.isfinite(float(np.asarray(metrics["loss"]).reshape(-1)[0]))
