"""Elastic membership vs fixed-n under worker churn: the robustness bench.

Replays a deterministic churn trace (worker 7 leaves, later rejoins)
against two trainers running the real jitted coded step on host meshes:

  fixed-n   the departed worker stays a permanent forced straggler at
            unchanged n (degradation rung 1 only): decode stays exact —
            the budget covers the hole — but every step pays the max of
            the alive workers (the drop budget is burnt on the hole)
  elastic   the full ladder: forced straggler -> zero-load re-plan ->
            resize to n_alive (prewarmed mesh, warm caches), then a
            scale-up resize back when the worker rejoins

Per step, total = modeled cluster wait (the order statistic a single
host cannot exhibit, drawn from the same shifted-exponential process as
``repro.bench.straggler`` with missing-heartbeat NaNs at down workers) +
measured wall of the jitted step.  The gated speedup uses the modeled
waits (scale-free and machine-independent); walls and recompile counts
are reported ungated.

Gated metrics:

  speedup_elastic_vs_fixed_n     modeled-wait total: the ladder beats
                                 paying the hole as a permanent straggler
  elastic_recovers_exact         after rejoin + scale-up the active code
                                 is bitwise-identical to a never-churned
                                 run's (C and decode weights)
  elastic_survives_past_s        a 2-departure burst past s=1 completes:
                                 partial-decode failover bridges the gap,
                                 the zero-load re-plan restores exact
  planner_resize_wins_long_horizon    membership-aware ranking: with the
                                 recompile charge amortized over a long
                                 remaining run, the resize candidate wins
  planner_degraded_wins_short_horizon ...and over a short horizon the
                                 stay-degraded candidate wins (the charge
                                 cannot be earned back)
"""

from __future__ import annotations

import dataclasses
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np

from repro.bench import BenchResult, BenchSpec, capture_env, register
from repro.configs import get_config
from repro.core import make_code
from repro.core.runtime_model import RuntimeParams
from repro.data import make_synthetic_batch
from repro.elastic import ElasticPolicy, ElasticTrainer, MembershipTrace
from repro.launch.mesh import make_local_mesh
from repro.optim import get_optimizer
from repro.tune import (StepRecord, WorkerTimes, rank_plans, step_cost_book,
                        synthetic_fit)

N_WORKERS = 8
#: divisible by 8 and 7, so both cluster sizes split the batch evenly
GLOBAL_BATCH = 56
DESIGN = (3, 1, 2)            # (d, s, m): s + m = d, the paper's optimum
# spot-fleet-style constants: small shifts, heavy straggler tail
# (lambda2=0.05 -> mean comm excess 20s) — the regime where spending the
# drop budget on a genuine straggler (instead of burning it on the hole a
# departed worker leaves) matters most
PARAMS = RuntimeParams(n=N_WORKERS, lambda1=0.5, lambda2=0.05, t1=0.5, t2=4.0)


class ChurnAwareSampler:
    """Injector ``(step, code) -> WorkerTimes`` with membership churn.

    Draws the shifted-exponential process of
    :class:`repro.tune.ShiftedExpSampler`, with two twists:

    - compute is **batch-aware across cluster sizes**: worker ``i``'s
      share of the global batch is ``loads[i] / k``, so the per-subset
      draw is scaled by ``ref_k / k`` — a 7-worker cluster's subsets are
      8/7 the size of an 8-worker cluster's;
    - workers named down by the scripted outage (and still inside the
      active code's index space) report **NaN** — the missing-heartbeat
      convention :meth:`repro.tune.WorkerTimes.order_stat` maps to
      ``+inf``, so they can never be counted as responders.

    Passed bare to the trainer it is wrapped in
    :class:`repro.tune.TimedSource` (slowest ``code.s`` workers per draw
    are the stragglers).
    """

    def __init__(self, down_worker: int, leave_step: int, rejoin_step: int,
                 seed: int = 0, ref_k: int = N_WORKERS):
        """``down_worker`` is unreachable for ``leave_step <= t <
        rejoin_step`` while the cluster still has its original size."""
        self.down_worker = down_worker
        self.leave_step = leave_step
        self.rejoin_step = rejoin_step
        self.ref_k = ref_k
        self._rng = np.random.default_rng(seed)

    def __call__(self, step: int, code) -> WorkerTimes:
        """One step's per-worker durations under the active scheme.

        Common random numbers: ``ref_k`` variates are drawn per step and
        sliced to the active ``n``, so runs that resize and runs that do
        not face the *same* per-worker noise — the wait comparison is
        paired, isolating the scheme effect from sampling variance.
        """
        n = code.n
        loads = np.asarray(getattr(code, "loads", (code.d,) * n),
                           dtype=np.float64)
        k = int(getattr(code, "num_subsets", n))
        scale = loads * self.ref_k / k
        x1 = self._rng.exponential(1.0 / PARAMS.lambda1, self.ref_k)[:n]
        x2 = self._rng.exponential(1.0 / PARAMS.lambda2, self.ref_k)[:n]
        comp = scale * (PARAMS.t1 + x1)
        comm = (PARAMS.t2 + x2) / code.m
        if (self.leave_step <= step < self.rejoin_step
                and self.down_worker < n == N_WORKERS):
            comp[self.down_worker] = np.nan
            comm[self.down_worker] = np.nan
        return WorkerTimes(compute_s=comp, comm_s=comm)


def _run(cfg, policy, trace, injector, steps):
    """Drive an ElasticTrainer; return (trainer, waits, walls, losses)."""
    code = make_code(N_WORKERS, *DESIGN)
    tr = ElasticTrainer(cfg, code, make_local_mesh(N_WORKERS, 1),
                        get_optimizer("sgd", 1e-2),
                        straggler_source=injector, churn=trace,
                        elastic=policy, seed=0)
    rng = np.random.default_rng(5)
    waits, walls, losses = [], [], []
    for _ in range(steps):
        m = tr.step(make_synthetic_batch(rng, cfg, GLOBAL_BATCH, 0))
        waits.append(m["modeled_wait_s"])
        walls.append(m["step_time_s"])
        losses.append(m["loss"])
    return tr, np.asarray(waits), np.asarray(walls), np.asarray(losses)


def _planner_membership_check(npts: int) -> tuple[float, float]:
    """Deterministic membership-aware ranking check (no wall-clock).

    Builds a cost book whose compile observations make a retrace
    expensive (a 30 s trace against a 20 ms step), then ranks
    stay-degraded vs resize for a departed worker under a long and a
    short re-plan horizon.
    """
    fit = synthetic_fit(PARAMS, steps=200, seed=7)
    n = N_WORKERS
    recs = [StepRecord(step=i, d=DESIGN[0], s=DESIGN[1], m=DESIGN[2], k=n,
                       loads=(DESIGN[0],) * n, schedule="gather", packed=True,
                       compute_s=np.full(n, 1.0), comm_s=np.full(n, 1.0),
                       measured_step_s=0.02, compile_s=30.0 if i == 0 else 0.0)
            for i in range(8)]
    book = step_cost_book(recs)
    common = dict(schedules=("gather",), cost_book=book, departed=(7,),
                  resize_options=(7,), mc_iters=300, npts=npts, seed=11)
    top_long = rank_plans(fit, replan_horizon=1000, **common)[0]
    top_short = rank_plans(fit, replan_horizon=1, **common)[0]
    return (float(top_long.resize_to == 7),
            float(top_short.resize_to is None))


def bench_results(quick: bool = False) -> list[BenchResult]:
    d_model = 256 if quick else 2048
    leave = 3 if quick else 6
    rejoin = 12 if quick else 26
    steps = 14 if quick else 30
    resize_after = 2 if quick else 3
    npts = 6_000 if quick else 20_000

    cfg = dataclasses.replace(get_config("logistic-paper"), d_model=d_model)
    trace = [(leave, "leave", 7), (rejoin, "join", 7)]

    # --- scenario 1: single departure + rejoin, fixed-n vs full ladder
    fixed_policy = ElasticPolicy(partial_failover=True, replan_after=0,
                                 resize_after=0, scale_up=False)
    elastic_policy = ElasticPolicy(partial_failover=True, replan_after=1,
                                   resize_after=resize_after, scale_up=True,
                                   min_n=2, prewarm=(N_WORKERS - 1,))
    tr_f, w_f, t_f, _ = _run(
        cfg, fixed_policy, MembershipTrace(trace),
        ChurnAwareSampler(7, leave, rejoin, seed=3), steps)
    tr_e, w_e, t_e, _ = _run(
        cfg, elastic_policy, MembershipTrace(trace),
        ChurnAwareSampler(7, leave, rejoin, seed=3), steps)

    metrics: dict[str, float] = {}
    lines = []
    metrics["wait_total_s_fixed"] = round(float(w_f.sum()), 3)
    metrics["wait_total_s_elastic"] = round(float(w_e.sum()), 3)
    metrics["wall_total_s_fixed"] = round(float(t_f.sum()), 3)
    metrics["wall_total_s_elastic"] = round(float(t_e.sum()), 3)
    metrics["speedup_elastic_vs_fixed_n"] = round(
        float(w_f.sum() / w_e.sum()), 4)
    down = slice(leave, rejoin)   # the outage window, where the claim lives
    metrics["wait_down_s_fixed"] = round(float(w_f[down].sum()), 3)
    metrics["wait_down_s_elastic"] = round(float(w_e[down].sum()), 3)
    metrics["speedup_down_window"] = round(
        float(w_f[down].sum() / w_e[down].sum()), 4)
    for name, (tr, w, t) in (("fixed", (tr_f, w_f, t_f)),
                             ("elastic", (tr_e, w_e, t_e))):
        lines.append(
            f"elastic,run={name},steps={steps},wait_total_s={w.sum():.2f},"
            f"wall_total_s={t.sum():.2f},final_n={tr.code.n}")
    for e in tr_e.elastic_events:
        lines.append("elastic_event," + ",".join(
            f"{k}={v}" for k, v in e.items()))

    # recovery: after rejoin + scale-up the code must be bitwise-identical
    # to a never-churned run's deterministic construction
    home = make_code(N_WORKERS, *DESIGN)
    resp = list(range(1, N_WORKERS))
    recovered = (tr_e.code.n == N_WORKERS
                 and np.array_equal(np.asarray(tr_e.code.C),
                                    np.asarray(home.C))
                 and np.array_equal(tr_e.code.decode_weights(resp),
                                    home.decode_weights(resp)))
    metrics["elastic_recovers_exact"] = float(recovered)
    metrics["elastic_n_resizes"] = float(sum(
        1 for e in tr_e.elastic_events if e["action"] == "resize"))

    # --- scenario 2: a 2-departure burst past s=1 (partial failover ->
    # zero-load re-plan restores exact decode at unchanged n)
    burst_steps = 8 if quick else 12
    tr_b, _, _, losses_b = _run(
        cfg, ElasticPolicy(partial_failover=True, replan_after=1,
                           resize_after=0, scale_up=False),
        MembershipTrace([(3, "preempt", 6), (3, "preempt", 7)]),
        ChurnAwareSampler(99, 10**9, 10**9, seed=4), burst_steps)
    acted = {e["action"] for e in tr_b.elastic_events}
    loads_b = np.asarray(getattr(tr_b.code, "loads",
                                 (tr_b.code.d,) * tr_b.code.n))
    survives = (np.isfinite(losses_b).all()
                and "partial-failover" in acted
                and "replan-degraded" in acted
                and loads_b[6] == 0 and loads_b[7] == 0
                and tr_b.code.s >= 2)
    metrics["elastic_survives_past_s"] = float(survives)
    lines.append(
        f"elastic_burst,steps={burst_steps},actions={sorted(acted)},"
        f"final_loads={list(loads_b)},final_s={tr_b.code.s}")

    # --- membership-aware planner: resize vs stay-degraded flips on the
    # recompile-amortization horizon
    long_ok, short_ok = _planner_membership_check(npts)
    metrics["planner_resize_wins_long_horizon"] = long_ok
    metrics["planner_degraded_wins_short_horizon"] = short_ok
    lines.append(
        f"elastic_planner,long_horizon_resize={int(long_ok)},"
        f"short_horizon_degraded={int(short_ok)}")

    result = BenchResult(
        name="elastic",
        metrics=metrics,
        params={"n_workers": N_WORKERS, "design": list(DESIGN),
                "global_batch": GLOBAL_BATCH, "d_model": d_model,
                "leave_step": leave, "rejoin_step": rejoin, "steps": steps,
                "resize_after": resize_after, "quick": quick,
                "params": dataclasses.asdict(PARAMS)},
        env=capture_env(mesh=make_local_mesh(N_WORKERS, 1)),
        timing={"warmup": 0, "reps": steps,
                "policy": "per-step blocked wall + modeled wait"},
        gates={"speedup_elastic_vs_fixed_n": "max",
               "elastic_recovers_exact": "max",
               "elastic_survives_past_s": "max",
               "planner_resize_wins_long_horizon": "max",
               "planner_degraded_wins_short_horizon": "max"},
        extra={"lines": lines, "events": tr_e.elastic_events},
    )
    return [result]


register(BenchSpec(
    name="elastic",
    description="elastic membership ladder vs fixed-n under worker churn",
    fn=bench_results,
    tags=("e2e", "train", "elastic"),
))


def run() -> list[str]:
    return bench_results(False)[0].extra["lines"]


if __name__ == "__main__":
    for line in run():
        print(line)
