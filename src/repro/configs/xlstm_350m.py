"""xlstm-350m [ssm] — 24L (alternating sLSTM / mLSTM blocks), d_model=1024,
4 heads, d_ff=0 (block-internal up/down projections), vocab=50304
[arXiv:2405.04517]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m", family="ssm",
    n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab=50304, head_dim=256,
    source="arXiv:2405.04517",
)
