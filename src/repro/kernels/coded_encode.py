"""Pallas TPU kernel: coded gradient ENCODE (paper eq. 17/18).

The encode is the per-step device hot-spot the paper's scheme adds on the
critical path between backprop and the collective: contract the worker's
``(d, m)`` coefficient rows against the grouped gradient ``(d, V, m[, R])``
to produce the ``(V[, R])`` transmitted vector.  Arithmetic intensity is
low (~1 FLOP/byte) — a pure streaming kernel, so the design goal is VMEM
tiling that keeps HBM traffic at exactly one read of G:

- grid over V tiles (x R tiles when a trailing model-sharded dim exists),
- each program loads the full (d, m) coefficient block (tiny) and a
  (d, TV, m[, TR]) gradient tile into VMEM, contracts, writes (TV[, TR]),
- tiles are multiples of (8, 128) in the last two dims for VPU lane/sublane
  alignment; d and m stay unblocked (d, m <= 32 in practice).

Validated against ref.coded_encode_ref in interpret mode (tests sweep
shapes x dtypes); ops.py exposes the jit'd wrapper with interpret fallback
on CPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


@functools.lru_cache(maxsize=None)
def pick_tile(size: int, target: int, align: int) -> int:
    """Largest divisor of ``size`` that is <= target, preferring multiples of
    ``align`` (VPU lane/sublane alignment); falls back to the largest divisor.

    Memoized: this O(size) scan runs at Python trace time for every leaf
    shape of every (re)trace — the zoo retraces the same handful of shapes
    constantly, so the cache turns it into a dict hit."""
    best = 1
    for t in range(min(target, size), 0, -1):
        if size % t:
            continue
        if t % align == 0:
            return t
        best = max(best, t)
    return best


def _encode_kernel_2d(g_ref, c_ref, o_ref):
    """g: (d, TV, m), c: (d, m), o: (TV,)."""
    g = g_ref[...].astype(jnp.float32)          # (d, TV, m)
    c = c_ref[...].astype(jnp.float32)          # (d, m)
    o_ref[...] = jnp.einsum("jvu,ju->v", g, c).astype(o_ref.dtype)


def _encode_kernel_3d(g_ref, c_ref, o_ref):
    """g: (d, TV, m, TR), c: (d, m), o: (TV, TR)."""
    g = g_ref[...].astype(jnp.float32)
    c = c_ref[...].astype(jnp.float32)
    o_ref[...] = jnp.einsum("jvur,ju->vr", g, c).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("tile_v", "tile_r", "interpret", "out_dtype"))
def coded_encode(G: jax.Array, C: jax.Array, *, tile_v: int = 512,
                 tile_r: int = 512, interpret: bool = False,
                 out_dtype=None) -> jax.Array:
    """G: (d, V, m) or (d, V, m, R); C: (d, m) -> (V,) or (V, R).

    out_dtype: accumulation happens in f32 in-kernel; the result is written in
    this dtype (default: G's dtype, matching the ref oracle).
    """
    d, V, m = G.shape[:3]
    out_dtype = jnp.dtype(out_dtype) if out_dtype is not None else G.dtype
    if G.ndim == 3:
        tv = pick_tile(V, tile_v, 128)
        grid = (V // tv,)
        return pl.pallas_call(
            _encode_kernel_2d,
            grid=grid,
            in_specs=[
                pl.BlockSpec((d, tv, m), lambda i: (0, i, 0)),
                pl.BlockSpec((d, m), lambda i: (0, 0)),
            ],
            out_specs=pl.BlockSpec((tv,), lambda i: (i,)),
            out_shape=jax.ShapeDtypeStruct((V,), out_dtype),
            interpret=interpret,
        )(G, C)
    # trailing model-sharded dim R: tile (V, R) as (8, 128)-aligned blocks so
    # narrow leaves (small local R after model sharding) still vectorize
    R = G.shape[3]
    tv = pick_tile(V, tile_v, 8)
    tr = pick_tile(R, tile_r, 128)
    grid = (V // tv, R // tr)
    return pl.pallas_call(
        _encode_kernel_3d,
        grid=grid,
        in_specs=[
            pl.BlockSpec((d, tv, m, tr), lambda i, j: (0, i, 0, j)),
            pl.BlockSpec((d, m), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tv, tr), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((V, R), out_dtype),
        interpret=interpret,
    )(G, C)


# ---------------------------------------------------------------- fused path
def _encode_acc_kernel_2d(a_ref, g_ref, c_ref, o_ref):
    """a: (TV,), g: (d, TV, m), c: (d, m), o: (TV,) — o = a + encode(g, c)."""
    g = g_ref[...].astype(jnp.float32)
    c = c_ref[...].astype(jnp.float32)
    o_ref[...] = (a_ref[...].astype(jnp.float32)
                  + jnp.einsum("jvu,ju->v", g, c)).astype(o_ref.dtype)


def _encode_acc_kernel_3d(a_ref, g_ref, c_ref, o_ref):
    """a: (TV, TR), g: (d, TV, m, TR), c: (d, m), o: (TV, TR)."""
    g = g_ref[...].astype(jnp.float32)
    c = c_ref[...].astype(jnp.float32)
    o_ref[...] = (a_ref[...].astype(jnp.float32)
                  + jnp.einsum("jvur,ju->vr", g, c)).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("tile_v", "tile_r", "interpret"))
def coded_encode_acc(acc: jax.Array, G: jax.Array, C: jax.Array, *,
                     tile_v: int = 512, tile_r: int = 512,
                     interpret: bool = False) -> jax.Array:
    """Accumulating encode: ``acc + coded_encode(G, C)`` in one pass.

    acc: (V,) or (V, R) f32 — one leaf's 128-aligned slot of a wire-bucket
    accumulator (``repro.coding.packing``); G: (d, V, m[, R]); C: (d, m).
    The pipelined step's fused encode path calls this once per (subset,
    leaf) so the wire buffer fills as gradient leaves materialise, instead
    of materialising every per-leaf encoding and concatenating in a later
    pack copy.  ``input_output_aliases`` updates the accumulator in place
    (the slot is consumed each fold); accumulation stays f32 in-kernel, so
    the fold is bit-identical to ``acc + coded_encode(G, C)``.
    """
    d, V, m = G.shape[:3]
    assert acc.dtype == jnp.float32, "wire accumulators are f32"
    if G.ndim == 3:
        tv = pick_tile(V, tile_v, 128)
        return pl.pallas_call(
            _encode_acc_kernel_2d,
            grid=(V // tv,),
            in_specs=[
                pl.BlockSpec((tv,), lambda i: (i,)),
                pl.BlockSpec((d, tv, m), lambda i: (0, i, 0)),
                pl.BlockSpec((d, m), lambda i: (0, 0)),
            ],
            out_specs=pl.BlockSpec((tv,), lambda i: (i,)),
            out_shape=jax.ShapeDtypeStruct((V,), jnp.float32),
            input_output_aliases={0: 0},
            interpret=interpret,
        )(acc, G, C)
    R = G.shape[3]
    tv = pick_tile(V, tile_v, 8)
    tr = pick_tile(R, tile_r, 128)
    return pl.pallas_call(
        _encode_acc_kernel_3d,
        grid=(V // tv, R // tr),
        in_specs=[
            pl.BlockSpec((tv, tr), lambda i, j: (i, j)),
            pl.BlockSpec((d, tv, m, tr), lambda i, j: (0, i, 0, j)),
            pl.BlockSpec((d, m), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tv, tr), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((V, R), jnp.float32),
        input_output_aliases={0: 0},
        interpret=interpret,
    )(acc, G, C)
