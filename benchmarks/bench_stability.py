"""Section III-C / IV-A numerical stability reproduction: worst-case relative
decode error (l-inf) vs n for the Vandermonde (eq. 23 thetas) and Gaussian
(Theorem 2) schemes.  Paper: Vandermonde stable to n<=20, ~80% error by n=23,
crashes by n=26; Gaussian stable to n~30."""

from __future__ import annotations

import math

import numpy as np

from repro.bench import BenchResult, BenchSpec, capture_env, register
from repro.core import GradCode
from repro.core.stability import sample_straggler_sets


def worst_decode_error(code: GradCode, trials: int = 20, l: int = 64,
                       seed: int = 0, straggler_sets: int = 30) -> float:
    """Max over random straggler sets of the relative decode error (seeded
    trial driver shared with the stability module's sweep)."""
    rng = np.random.default_rng(seed)
    worst = 0.0
    for t in range(trials):
        G = rng.standard_normal((code.n, l))
        want = G.sum(0)
        F = code.encode(G)
        for st in sample_straggler_sets(code.n, (0, code.s), straggler_sets,
                                        seed=seed + 7919 * (t + 1),
                                        dedupe=False):
            resp = np.setdiff1d(np.arange(code.n), st)
            got = code.decode(F, resp)
            err = np.max(np.abs(got - want)) / max(np.max(np.abs(want)), 1e-12)
            worst = max(worst, float(err))
    return worst


def sweep(kind: str, ns=(5, 8, 10, 14, 16, 20, 23, 26, 30), d=None, m=2,
          trials: int = 5, straggler_sets: int = 10):
    rows = {}
    for n in ns:
        dd = d or max(3, n // 3)
        code = GradCode(n=n, d=dd, s=dd - m, m=m, kind=kind)
        try:
            rows[n] = worst_decode_error(code, trials=trials,
                                         straggler_sets=straggler_sets)
        except Exception:  # noqa: BLE001 — "our algorithm crushes"
            rows[n] = float("inf")
    return rows


def bench_results(quick: bool = False) -> list[BenchResult]:
    ns = (8, 14, 20, 23, 30) if quick else (5, 8, 10, 14, 16, 20, 23, 26, 30)
    trials = 3 if quick else 5
    sets = 6 if quick else 10
    vand = sweep("poly", ns=ns, trials=trials, straggler_sets=sets)
    gaus = sweep("random", ns=ns, trials=trials, straggler_sets=sets)
    lines = []
    for n in sorted(vand):
        lines.append(f"stability,n={n},vandermonde={vand[n]:.3e},"
                     f"gaussian={gaus[n]:.3e}")
    # the paper's qualitative boundaries (paper: rel err < 0.2% to n=20, up
    # to 80% at n=23, crash at 26; we observe ~0.7% worst case at n=20 with
    # our d-sweep — same order, boundary in the same place)
    ok_v20 = all(vand[n] < 2e-2 for n in vand if n <= 20)
    bad_v23 = vand.get(23, 0) > 0.05 or vand.get(26, 0) > 0.05
    ok_g30 = all(gaus[n] < 2e-3 for n in gaus if n <= 30)
    lines.append(f"stability_boundaries,vandermonde_ok_to_20={ok_v20},"
                 f"vandermonde_unstable_23plus={bad_v23},gaussian_ok_to_30={ok_g30}")

    def crashsafe(x: float):
        return "crash" if math.isinf(x) else x

    # metrics must be finite: a decode crash (inf) is clamped so the record
    # stays schema-valid and the boundary booleans above carry the regression
    # signal to the gate (the raw inf is preserved in extra via crashsafe)
    CRASH = 1e12

    result = BenchResult(
        name="stability",
        metrics={
            "vandermonde_ok_to_20": float(ok_v20),
            "vandermonde_unstable_23plus": float(bad_v23),
            "gaussian_ok_to_30": float(ok_g30),
            "worst_vandermonde_n20": min(float(vand[20]), CRASH),
            "worst_gaussian_n30": min(float(gaus[30]), CRASH),
        },
        params={"ns": list(ns), "trials": trials, "straggler_sets": sets,
                "m": 2, "quick": quick},
        env=capture_env(),
        gates={"vandermonde_ok_to_20": "max",
               "vandermonde_unstable_23plus": "max",
               "gaussian_ok_to_30": "max"},
        extra={"lines": lines,
               "vandermonde": {str(n): crashsafe(v) for n, v in vand.items()},
               "gaussian": {str(n): crashsafe(v) for n, v in gaus.items()}},
    )
    return [result]


register(BenchSpec(
    name="stability",
    description="Sec III-C/IV-A stability boundaries",
    fn=bench_results,
    tags=("model",),
))


def run() -> list[str]:
    return bench_results(False)[0].extra["lines"]


if __name__ == "__main__":
    for line in run():
        print(line)
