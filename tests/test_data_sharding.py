"""Data pipeline placement + sharding-rule unit tests (with hypothesis
properties on the placement bijection)."""
import jax
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # declared in pyproject [test]; optional at runtime
from hypothesis import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.coding import plan_tree
from repro.configs import get_config
from repro.core import make_code
from repro.data import CodedBatcher, make_synthetic_batch
from repro.models import api as model_api
from repro.train import sharding


# ------------------------------------------------------------ CodedBatcher
@settings(max_examples=30, deadline=None)
@given(n=st.integers(2, 12), dm=st.tuples(st.integers(1, 6), st.integers(1, 4)),
       b=st.integers(1, 3))
def test_placement_covers_every_subset_d_times(n, dm, b):
    d_extra, m = dm
    m = min(m, n)
    d = min(n, m + d_extra - 1)
    if d < m:
        return
    code = make_code(n, d, d - m, m)
    batcher = CodedBatcher(code)
    x = np.arange(n * b, dtype=np.int64)[:, None] * np.ones((1, 3))
    placed = batcher.place({"x": x})["x"]        # (n, d, b, 3)
    assert placed.shape == (n, d, b, 3)
    # worker i's slot j holds subset (i+j) % n
    for i in range(n):
        for j in range(d):
            sub = (i + j) % n
            np.testing.assert_array_equal(placed[i, j, :, 0],
                                          np.arange(sub * b, (sub + 1) * b))
    # every subset appears exactly d times
    ids = placed[:, :, 0, 0] // b
    counts = np.bincount(ids.astype(int).ravel(), minlength=n)
    assert (counts == d).all()


def test_place_rejects_indivisible_batch():
    code = make_code(4, 3, 1, 2)
    with pytest.raises(ValueError):
        CodedBatcher(code).place({"x": np.zeros((7, 2))})


def test_synthetic_batches_have_expected_keys():
    rng = np.random.default_rng(0)
    for arch, keys in [("qwen3-8b", {"tokens", "labels"}),
                       ("internvl2-26b", {"tokens", "labels", "embeds"}),
                       ("whisper-tiny", {"tokens", "labels", "embeds"})]:
        cfg = get_config(arch).reduced()
        assert set(make_synthetic_batch(rng, cfg, 4, 16)) == keys


# ------------------------------------------------------------ param specs
def test_param_specs_respect_divisibility():
    cfg = get_config("qwen2-72b")  # kv=8 < 16 -> kv heads replicated
    shapes = jax.eval_shape(lambda: model_api.init(jax.random.PRNGKey(0), cfg))
    specs = sharding.param_specs(shapes, 16)
    flat = jax.tree_util.tree_flatten_with_path(specs)[0]
    for path, spec in flat:
        leaf = shapes
        for p in path:
            leaf = leaf[p.key]
        for dim, entry in enumerate(spec):
            if entry == "model":
                assert leaf.shape[dim] % 16 == 0, (path, leaf.shape, spec)
    # q heads (64) sharded, kv heads (8) replicated
    attn = specs["layers"]["attn"]
    assert attn["wq"][2] == "model"
    assert attn["wk"][2] is None
    assert specs["embed"][0] == "model"          # vocab parallel
    assert specs["unembed"][1] == "model"


def test_param_specs_moe_expert_axis():
    specs64 = sharding.param_specs(
        jax.eval_shape(lambda: model_api.init(
            jax.random.PRNGKey(0), get_config("olmoe-1b-7b"))), 16)
    assert specs64["layers"]["moe"]["w_gate"][1] == "model"   # 64 experts
    specs8 = sharding.param_specs(
        jax.eval_shape(lambda: model_api.init(
            jax.random.PRNGKey(0), get_config("grok-1-314b"))), 16)
    # 8 experts not divisible by 16 -> shard d_ff instead
    assert specs8["layers"]["moe"]["w_gate"][1] is None
    assert specs8["layers"]["moe"]["w_gate"][3] == "model"


def test_plan_tree_picks_model_replicated_dim():
    cfg = get_config("qwen3-1.7b")
    shapes = jax.eval_shape(lambda: model_api.init(jax.random.PRNGKey(0), cfg))
    specs = sharding.param_specs(shapes, 16)
    plans = plan_tree(shapes, specs, m=2)
    flat_sh = jax.tree_util.tree_flatten_with_path(shapes)[0]
    flat_pl = jax.tree.leaves(plans, is_leaf=lambda x: hasattr(x, "coded"))
    flat_sp = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    n_coded = 0
    for (path, sh), pl, sp in zip(flat_sh, flat_pl, flat_sp):
        if pl.coded:
            n_coded += 1
            assert sh.shape[pl.group_dim] % 2 == 0
            assert sp[pl.group_dim] is None, (path, sp, pl)
    assert n_coded > 0


def test_cache_specs_batch_and_model_dims():
    cfg = get_config("qwen3-8b")
    cshapes = model_api.cache_spec(cfg, 128, 32768)
    specs = sharding.cache_specs(cshapes, ("data",), 16, 16)
    assert specs["k"][1] == "data"
    assert "model" in tuple(specs["k"])
    # batch=1 long context: replicate batch
    cshapes1 = model_api.cache_spec(cfg, 1, 524288, window=4096)
    specs1 = sharding.cache_specs(cshapes1, ("data",), 16, 16)
    assert specs1["k"][1] is None
